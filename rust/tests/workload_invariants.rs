//! The adversarial-workload plane's load-bearing invariants, tested end
//! to end:
//!
//! 1. **Replay** — every generator in `tricluster::workload` is a pure
//!    function of its parameters and seed: two calls produce
//!    BIT-identical streams/schedules, for randomized parameters.
//! 2. **Declared distributions** — skew concentrates mass on rank 0,
//!    drift moves the id window segment by segment, burst follows its
//!    cadence, correlated kills take ADJACENT nodes in the
//!    placement-load ranking.
//! 3. **Isolation + equivalence** — for randomized tenant mixes,
//!    workloads, quotas, and correlated-kill schedules on a shared
//!    `MultiTenantSim` pool: each tenant's compacted index equals that
//!    tenant's solo `mine_online` over exactly the tuples its quota
//!    accepted, and equals a solo pool run of the same tenant —
//!    neighbours may slow a tenant, never perturb it.

mod common;

use common::{assert_same, deal_streams, distinct_ctx, random_ctx, sorted};
use tricluster::core::context::PolyContext;
use tricluster::core::tuple::NTuple;
use tricluster::oac::{mine_online, Constraints};
use tricluster::serve::tenant::{MultiTenantSim, TenantPoolConfig, TenantSpec};
use tricluster::util::proptest_lite::{assert_prop, Gen};
use tricluster::workload::{
    correlated_kills, BurstMix, DriftingStream, Op, SkewedStream,
};

/// Every generator, randomized parameters, fresh seeds: generate twice,
/// compare bit-for-bit. This is the contract that makes every
/// adversarial failure reproducible from `(params, seed)` alone.
#[test]
fn prop_generators_replay_bit_identically() {
    assert_prop(64, |g: &mut Gen| {
        let seed = g.rng.next_u64();
        let arity = 3 + g.usize_below(2);

        let skew = SkewedStream {
            tuples: 1 + g.usize_below(300),
            universe: 1 + g.rng.below(40),
            exponent: g.f64() * 3.0,
            arity,
        };
        if skew.generate(seed) != skew.generate(seed) {
            return Err(format!("SkewedStream replay diverged: {skew:?}"));
        }

        let drift = DriftingStream {
            tuples: 1 + g.usize_below(300),
            universe: 1 + g.rng.below(30),
            segments: 1 + g.usize_below(6),
            shift: g.u32_below(40),
            arity,
        };
        if drift.generate(seed) != drift.generate(seed) {
            return Err(format!("DriftingStream replay diverged: {drift:?}"));
        }

        let burst = BurstMix {
            waves: 1 + g.usize_below(10),
            steady_batch: 1 + g.usize_below(40),
            burst_batch: 1 + g.usize_below(200),
            burst_every: g.usize_below(5),
            queries_per_wave: g.usize_below(6),
            universe: 1 + g.rng.below(40),
            arity,
        };
        if burst.generate(seed) != burst.generate(seed) {
            return Err(format!("BurstMix replay diverged: {burst:?}"));
        }

        Ok(())
    });
}

/// Kill schedules replay bit-identically for identical arguments (the
/// prop above varies stream generators; this pins the failure
/// generator with exactly-equal inputs).
#[test]
fn prop_kill_schedules_replay_bit_identically() {
    assert_prop(64, |g: &mut Gen| {
        let seed = g.rng.next_u64();
        let nodes = 1 + g.usize_below(6);
        let assignment: Vec<usize> =
            (0..1 + g.usize_below(8)).map(|_| g.usize_below(nodes)).collect();
        let set_size = 1 + g.usize_below(nodes);
        let events = 1 + g.usize_below(4);
        let waves = 1 + g.usize_below(12);
        let a = correlated_kills(&assignment, nodes, set_size, events, waves, seed);
        let b = correlated_kills(&assignment, nodes, set_size, events, waves, seed);
        if a != b {
            return Err(format!("kill schedule replay diverged: {a:?} vs {b:?}"));
        }
        if a.len() != events {
            return Err(format!("{} events, asked for {events}", a.len()));
        }
        for k in &a {
            if k.victims.len() != set_size || k.wave >= waves {
                return Err(format!("event out of envelope: {k:?}"));
            }
        }
        if !a.windows(2).all(|w| w[0].wave <= w[1].wave) {
            return Err("events not sorted by wave".into());
        }
        Ok(())
    });
}

/// Heavy-hitter skew: at exponent 2 the rank-0 entity takes a large
/// multiple of the uniform share; at exponent 0 it does not.
#[test]
fn skew_concentrates_exactly_when_asked_to() {
    let count_rank0 = |exponent: f64| {
        let stream = SkewedStream { tuples: 4000, universe: 50, exponent, arity: 3 }
            .generate(11);
        assert_eq!(stream.len(), 4000);
        stream.iter().filter(|t| t.get(0) == 0).count()
    };
    let uniform_share = 4000 / 50; // 80
    let hot = count_rank0(2.0);
    assert!(hot > uniform_share * 10, "zipf(2.0) rank-0 count {hot} too flat");
    let flat = count_rank0(0.0);
    assert!(
        flat < uniform_share * 3,
        "zipf(0.0) should be near-uniform, rank-0 count {flat}"
    );
}

/// Temporal drift: every segment's ids stay inside its declared window
/// `[base, base + universe)`, and the window actually moves.
#[test]
fn drift_window_moves_and_stays_in_bounds() {
    let drift =
        DriftingStream { tuples: 120, universe: 10, segments: 4, shift: 100, arity: 3 };
    let stream = drift.generate(5);
    assert_eq!(stream.len(), 120);
    let seg_len = 30;
    for (i, tuple) in stream.iter().enumerate() {
        let base = (i / seg_len) as u32 * 100;
        for k in 0..3 {
            let id = tuple.get(k);
            assert!(
                (base..base + 10).contains(&id),
                "tuple {i} component {k}: id {id} outside window [{base}, {})",
                base + 10
            );
        }
    }
    // distinct windows share no ids (shift > universe) — drift is real
    let first_seg: Vec<u32> = stream[..30].iter().map(|t| t.get(0)).collect();
    let last_seg: Vec<u32> = stream[90..].iter().map(|t| t.get(0)).collect();
    assert!(first_seg.iter().all(|id| !last_seg.contains(id)));
}

/// Burst cadence: every `burst_every`-th wave ingests the burst batch,
/// the others the steady batch, with the declared query mix in between.
#[test]
fn burst_mix_follows_its_cadence() {
    let mix = BurstMix {
        waves: 6,
        steady_batch: 10,
        burst_batch: 50,
        burst_every: 3,
        queries_per_wave: 2,
        universe: 32,
        arity: 3,
    };
    let ops = mix.generate(21);
    let ingests: Vec<usize> = ops
        .iter()
        .filter_map(|op| match op {
            Op::Ingest(batch) => Some(batch.len()),
            Op::Query(_) => None,
        })
        .collect();
    assert_eq!(ingests, vec![10, 10, 50, 10, 10, 50]);
    let queries = ops.iter().filter(|op| matches!(op, Op::Query(_))).count();
    assert_eq!(queries, 12);
}

/// A randomized tenant spec for the isolation property: per-tenant θ,
/// shard count, quota, and stream flavour.
fn random_spec(g: &mut Gen, t: usize) -> TenantSpec {
    let mut spec = TenantSpec::new(&format!("tenant-{t}"), 3);
    spec.shards = 1 + g.usize_below(4);
    spec.constraints = if g.bool(0.5) {
        Constraints::none()
    } else {
        Constraints { min_density: g.f64(), min_support: g.usize_below(3) }
    };
    if g.bool(0.3) {
        spec.quota = 1 + g.usize_below(60);
    }
    spec
}

/// One tenant's stream: skew, drift, or a plain random context.
fn random_stream(g: &mut Gen, n: usize) -> Vec<NTuple> {
    let seed = g.rng.next_u64();
    match g.usize_below(3) {
        0 => SkewedStream {
            tuples: n,
            universe: 4 + g.rng.below(10),
            exponent: 0.5 + g.f64() * 2.0,
            arity: 3,
        }
        .generate(seed),
        1 => DriftingStream {
            tuples: n,
            universe: 3 + g.rng.below(6),
            segments: 1 + g.usize_below(4),
            shift: g.u32_below(6),
            arity: 3,
        }
        .generate(seed),
        _ => random_ctx(g, 3, 2 + g.u32_below(8), n).tuples().to_vec(),
    }
}

/// What the pool must have accepted from `stream`: the quota PREFIX of
/// every `batch`-sized wave (the documented acceptance rule).
fn accepted_prefix(stream: &[NTuple], batch: usize, quota: usize) -> PolyContext {
    let mut ctx = PolyContext::new(3);
    for wave in stream.chunks(batch) {
        for tuple in &wave[..wave.len().min(quota)] {
            ctx.add_ids(tuple.as_slice());
        }
    }
    ctx
}

/// THE tentpole invariant. Randomized tenant mixes (1–4 tenants with
/// independent θ/shards/quotas), adversarial per-tenant streams,
/// correlated node kills: every tenant's compacted index equals
/// `mine_online` over exactly its accepted tuples under ITS
/// constraints, and equals the same tenant run SOLO on its own pool —
/// so a neighbour's load provably never leaks into a tenant's results.
#[test]
fn prop_tenant_isolation_and_equivalence_under_churn() {
    assert_prop(24, |g: &mut Gen| {
        let tenants = 1 + g.usize_below(4);
        let nodes = 1 + g.usize_below(4);
        let batch = 8 + g.usize_below(56);
        let compact_every = 1 + g.usize_below(4);
        let placement = ["rr", "locality", "least"][g.usize_below(3)];

        let mut cfg = TenantPoolConfig::new(nodes);
        cfg.placement = placement.into();
        cfg.slots_per_node = 1 + g.usize_below(3);
        cfg.seed = g.rng.next_u64();
        for t in 0..tenants {
            cfg = cfg.tenant(random_spec(g, t));
        }
        let streams: Vec<Vec<NTuple>> =
            (0..tenants).map(|_| random_stream(g, 30 + g.usize_below(220))).collect();

        let mut sim = MultiTenantSim::new(cfg.clone()).map_err(|e| e.to_string())?;
        let kills = if g.bool(0.5) && nodes > 1 {
            let waves = streams
                .iter()
                .map(|s| s.len().div_ceil(batch))
                .max()
                .unwrap_or(1);
            correlated_kills(
                sim.assignment(0),
                nodes,
                1 + g.usize_below(nodes),
                1 + g.usize_below(2),
                waves,
                g.rng.next_u64(),
            )
        } else {
            Vec::new()
        };
        sim.run(&streams, batch, compact_every, &kills);

        for t in 0..tenants {
            let spec = &cfg.tenants[t];
            let label = format!(
                "tenant {t}/{tenants}: {placement} nodes={nodes} shards={} \
                 quota={} batch={batch} kills={}",
                spec.shards,
                spec.quota,
                kills.len()
            );
            // equivalence: pool index == solo mine_online over the
            // accepted prefix, under THIS tenant's constraints
            let accepted = accepted_prefix(&streams[t], batch, spec.quota);
            let reference = sorted(mine_online(&accepted, &spec.constraints));
            let got = sorted(sim.clusters(t).to_vec());
            assert_same(&got, &reference, &label)?;

            // isolation: the same tenant alone on an otherwise-identical
            // pool (no neighbours, no correlated kills) answers the same
            let mut solo_cfg = TenantPoolConfig::new(nodes);
            solo_cfg.placement = cfg.placement.clone();
            solo_cfg.slots_per_node = cfg.slots_per_node;
            solo_cfg.seed = cfg.seed;
            let solo_cfg = solo_cfg.tenant(spec.clone());
            let mut solo =
                MultiTenantSim::new(solo_cfg).map_err(|e| e.to_string())?;
            solo.run(
                std::slice::from_ref(&streams[t]),
                batch,
                compact_every,
                &[],
            );
            let alone = sorted(solo.clusters(0).to_vec());
            assert_same(&got, &alone, &format!("{label} vs solo pool"))?;
        }
        if sim.fairness_spread() < 1.0 {
            return Err("fairness spread below 1.0".into());
        }
        Ok(())
    });
}

/// A zero-quota tenant (constructed directly — the builder rejects it)
/// accepts nothing, indexes nothing, and leaves every neighbour's index
/// exactly as it would be without it.
#[test]
fn zero_quota_tenant_is_inert() {
    let ctx = distinct_ctx(31, 240, 9);
    let streams = deal_streams(&ctx, 2);

    let with_starved = {
        let mut starved = TenantSpec::new("starved", 3);
        starved.quota = 0;
        let cfg = TenantPoolConfig::new(3)
            .tenant(TenantSpec::new("busy", 3))
            .tenant(starved);
        let mut sim = MultiTenantSim::new(cfg).unwrap();
        sim.run(&[streams[0].clone(), streams[1].clone()], 32, 2, &[]);
        assert_eq!(sim.stats().accepted[1], 0);
        assert_eq!(sim.stats().throttled[1], streams[1].len());
        assert!(sim.clusters(1).is_empty(), "zero quota must index nothing");
        sorted(sim.clusters(0).to_vec())
    };
    let without = {
        let cfg = TenantPoolConfig::new(3).tenant(TenantSpec::new("busy", 3));
        let mut sim = MultiTenantSim::new(cfg).unwrap();
        sim.run(std::slice::from_ref(&streams[0]), 32, 2, &[]);
        sorted(sim.clusters(0).to_vec())
    };
    assert_same(&with_starved, &without, "starved neighbour perturbed tenant 0")
        .unwrap();
}

/// An all-duplicate stream is one logical tuple however it is split
/// across tenants, waves, and compactions.
#[test]
fn all_duplicate_stream_collapses_to_one_tuple() {
    let stream: Vec<NTuple> = vec![NTuple::triple(7, 7, 7); 500];
    let cfg = TenantPoolConfig::new(2)
        .tenant(TenantSpec::new("a", 3))
        .tenant(TenantSpec::new("b", 3));
    let mut sim = MultiTenantSim::new(cfg).unwrap();
    sim.run(&[stream.clone(), stream], 64, 3, &[]);
    for t in 0..2 {
        let clusters = sim.clusters(t).to_vec();
        assert_eq!(clusters.len(), 1, "tenant {t}");
        assert_eq!(clusters[0].support, 1, "duplicates must count once");
    }
}
