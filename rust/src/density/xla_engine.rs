//! The XLA/Pallas density engine: batched counts on dense tiles through
//! the AOT artifact (Layer-1 kernel on the PJRT CPU client).
//!
//! Execution plan per call: build `DenseTiles` once, then for every batch
//! of K clusters and every tile run `density_g{T}_k{K}`, accumulating
//! per-cluster counts. Volumes come from the cluster components (exact).

use anyhow::Result;

use crate::core::context::TriContext;
use crate::core::pattern::Cluster;
use crate::density::tiling::{tile_mask, DenseTiles};
use crate::density::DensityEngine;
use crate::runtime::{DensityExecutable, Runtime};

/// Density engine backed by an AOT-compiled JAX/Pallas kernel via PJRT.
pub struct XlaEngine {
    exe: DensityExecutable,
    /// reuse tiles across calls for the same context (keyed by ptr+len)
    cached: Option<(usize, DenseTiles)>,
}

impl XlaEngine {
    /// Compile the best-fitting density artifact for the given context
    /// size and typical batch.
    pub fn new(rt: &Runtime, edge: usize, batch: usize) -> Result<Self> {
        Ok(Self { exe: rt.best_density(edge, batch)?, cached: None })
    }

    /// Tile edge the compiled kernel expects.
    pub fn tile(&self) -> usize {
        self.exe.tile
    }

    /// Cluster-batch size the compiled kernel expects.
    pub fn k(&self) -> usize {
        self.exe.k
    }

    /// Raw batched counts: Σ_tiles kernel(tile, masks). Exposed for the
    /// perf bench; `densities` wraps it.
    pub fn counts(&mut self, ctx: &TriContext, clusters: &[Cluster]) -> Result<Vec<f64>> {
        let t = self.exe.tile;
        let k = self.exe.k;
        let key = ctx.len() ^ (ctx.sizes().0 << 24);
        if self.cached.as_ref().map(|(c, _)| *c) != Some(key) {
            self.cached = Some((key, DenseTiles::build(ctx, t)));
        }
        let tiles = &self.cached.as_ref().unwrap().1;
        let mut counts = vec![0f64; clusters.len()];

        let mut xm = vec![0f32; k * t];
        let mut ym = vec![0f32; k * t];
        let mut zm = vec![0f32; k * t];
        for (batch_idx, batch) in clusters.chunks(k).enumerate() {
            for gi in 0..tiles.grid.0 {
                // slice X masks for this tile row once per (batch, gi)
                xm.fill(0.0);
                for (j, c) in batch.iter().enumerate() {
                    tile_mask(&c.components[0], gi, t, &mut xm[j * t..(j + 1) * t]);
                }
                for mi in 0..tiles.grid.1 {
                    ym.fill(0.0);
                    for (j, c) in batch.iter().enumerate() {
                        tile_mask(&c.components[1], mi, t, &mut ym[j * t..(j + 1) * t]);
                    }
                    for bi in 0..tiles.grid.2 {
                        zm.fill(0.0);
                        for (j, c) in batch.iter().enumerate() {
                            tile_mask(
                                &c.components[2],
                                bi,
                                t,
                                &mut zm[j * t..(j + 1) * t],
                            );
                        }
                        let (cnt, _vol) =
                            self.exe.run(tiles.tile(gi, mi, bi), &xm, &ym, &zm)?;
                        for j in 0..batch.len() {
                            counts[batch_idx * k + j] += cnt[j] as f64;
                        }
                    }
                }
            }
        }
        Ok(counts)
    }
}

impl DensityEngine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla-pallas"
    }

    fn densities(&mut self, ctx: &TriContext, clusters: &[Cluster]) -> Vec<f64> {
        let counts = self.counts(ctx, clusters).expect("xla density execution");
        counts
            .iter()
            .zip(clusters)
            .map(|(&cnt, c)| {
                let vol = c.volume();
                if vol == 0.0 {
                    0.0
                } else {
                    cnt / vol
                }
            })
            .collect()
    }
}
