//! N-ary tuples over interned entity ids.
//!
//! The paper's multimodal generalisation (§3.1) works over polyadic
//! contexts up to arity N; we support `N ≤ MAX_ARITY` with an inline array
//! (no heap allocation per tuple — there are up to 10⁶ of them in the
//! Table-4 runs and each M/R stage re-materialises them).

use std::fmt;

/// Maximum supported relation arity (paper evaluates N = 3 and N = 4).
pub const MAX_ARITY: usize = 6;

/// One input tuple `(e_1, …, e_N)`; `e_k` is an id in modality k's
/// interner space.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NTuple {
    elems: [u32; MAX_ARITY],
    arity: u8,
}

impl NTuple {
    /// Tuple over `elems` (panics unless `2 ≤ arity ≤ MAX_ARITY`).
    pub fn new(elems: &[u32]) -> Self {
        assert!(
            (2..=MAX_ARITY).contains(&elems.len()),
            "arity {} out of range 2..={MAX_ARITY}",
            elems.len()
        );
        let mut buf = [0u32; MAX_ARITY];
        buf[..elems.len()].copy_from_slice(elems);
        Self { elems: buf, arity: elems.len() as u8 }
    }

    /// Arity-3 convenience constructor (the paper's `(g, m, b)`).
    pub fn triple(g: u32, m: u32, b: u32) -> Self {
        Self::new(&[g, m, b])
    }

    #[inline]
    /// Number of components.
    pub fn arity(&self) -> usize {
        self.arity as usize
    }

    #[inline]
    /// Component `k` (0-based).
    pub fn get(&self, k: usize) -> u32 {
        debug_assert!(k < self.arity());
        self.elems[k]
    }

    #[inline]
    /// The components as a slice.
    pub fn as_slice(&self) -> &[u32] {
        &self.elems[..self.arity()]
    }

    /// The subrelation `(e_1, …, e_{k-1}, e_{k+1}, …, e_N)` — the First Map
    /// key of Algorithm 2, tagged with the dropped position `k`.
    pub fn subrelation(&self, k: usize) -> SubRelation {
        debug_assert!(k < self.arity());
        let mut buf = [0u32; MAX_ARITY];
        let mut j = 0;
        for (i, &e) in self.as_slice().iter().enumerate() {
            if i != k {
                buf[j] = e;
                j += 1;
            }
        }
        SubRelation { elems: buf, arity: self.arity, dropped: k as u8 }
    }

    /// Rebuild the generating tuple by re-inserting `e` at the dropped
    /// position (Second Map, Algorithm 4).
    pub fn from_subrelation(sub: &SubRelation, e: u32) -> Self {
        let n = sub.arity as usize;
        let k = sub.dropped as usize;
        let mut buf = [0u32; MAX_ARITY];
        let mut j = 0;
        for i in 0..n {
            if i == k {
                buf[i] = e;
            } else {
                buf[i] = sub.elems[j];
                j += 1;
            }
        }
        Self { elems: buf, arity: sub.arity }
    }
}

impl fmt::Debug for NTuple {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "NTuple{:?}", self.as_slice())
    }
}

/// A tuple with one position removed; key of the first M/R stage.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct SubRelation {
    elems: [u32; MAX_ARITY],
    /// arity of the ORIGINAL tuple
    arity: u8,
    /// which position was dropped
    dropped: u8,
}

impl SubRelation {
    /// Rebuild a subrelation from its kept components (in original
    /// order) and the dropped position — the inverse of
    /// [`NTuple::subrelation`] over `(kept, dropped)`, used by the
    /// prime-store ingest kernel to export packed `u128` keys back as
    /// subrelations. Panics unless `kept.len() + 1 ≤ MAX_ARITY` and
    /// `dropped ≤ kept.len()`.
    pub fn from_parts(kept: &[u32], dropped: usize) -> Self {
        let arity = kept.len() + 1;
        assert!(
            (2..=MAX_ARITY).contains(&arity),
            "subrelation arity {arity} out of range 2..={MAX_ARITY}"
        );
        assert!(dropped < arity, "dropped position {dropped} out of range");
        let mut buf = [0u32; MAX_ARITY];
        buf[..kept.len()].copy_from_slice(kept);
        Self { elems: buf, arity: arity as u8, dropped: dropped as u8 }
    }

    #[inline]
    /// Which position was dropped (the subrelation's modality tag).
    pub fn dropped(&self) -> usize {
        self.dropped as usize
    }

    #[inline]
    /// Arity of the original tuple this subrelation came from.
    pub fn original_arity(&self) -> usize {
        self.arity as usize
    }

    #[inline]
    /// The kept components, in original order.
    pub fn as_slice(&self) -> &[u32] {
        &self.elems[..self.arity as usize - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::assert_prop;

    #[test]
    fn subrelation_roundtrip_triple() {
        let t = NTuple::triple(7, 8, 9);
        for k in 0..3 {
            let sub = t.subrelation(k);
            assert_eq!(sub.dropped(), k);
            let back = NTuple::from_subrelation(&sub, t.get(k));
            assert_eq!(back, t);
        }
    }

    #[test]
    fn subrelation_contents() {
        let t = NTuple::new(&[1, 2, 3, 4]);
        assert_eq!(t.subrelation(0).as_slice(), &[2, 3, 4]);
        assert_eq!(t.subrelation(2).as_slice(), &[1, 2, 4]);
        assert_eq!(t.subrelation(3).as_slice(), &[1, 2, 3]);
    }

    #[test]
    fn subrelations_of_different_positions_differ() {
        // (a,a,b) dropped at 0 vs 1 both give (a,b) — the `dropped` tag must
        // keep them distinct (this is why the M/R key includes k).
        let t = NTuple::triple(5, 5, 6);
        assert_ne!(t.subrelation(0), t.subrelation(1));
    }

    #[test]
    #[should_panic]
    fn arity_too_large_panics() {
        NTuple::new(&[0; MAX_ARITY + 1]);
    }

    #[test]
    fn from_parts_inverts_subrelation() {
        let t = NTuple::new(&[4, 9, 2, 7]);
        for k in 0..4 {
            let sub = t.subrelation(k);
            assert_eq!(SubRelation::from_parts(sub.as_slice(), k), sub);
        }
    }

    #[test]
    fn prop_roundtrip_any_arity() {
        assert_prop(128, |g| {
            let n = 2 + g.usize_below(MAX_ARITY - 1);
            let elems: Vec<u32> = (0..n).map(|_| g.u32_below(1000)).collect();
            let t = NTuple::new(&elems);
            for k in 0..n {
                let back = NTuple::from_subrelation(&t.subrelation(k), t.get(k));
                if back != t {
                    return Err(format!("roundtrip failed at k={k} for {t:?}"));
                }
            }
            Ok(())
        });
    }
}
