//! Minimal JSON parser + writer.
//!
//! Needed to read `artifacts/manifest.json` (written by python/compile/aot.py)
//! from the Rust runtime, and to emit machine-readable experiment reports.
//! No serde_json offline; the subset implemented here is complete for
//! RFC 8259 documents without surrogate-pair escapes in keys we produce.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
/// A parsed JSON value.
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (members kept in key order).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse one JSON document (rejects trailing input).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { s: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object member `key`, if this is an object containing it.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element `i`, if this is an array long enough.
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The number payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number payload truncated to usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// The bool payload, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The members, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len()
            && matches!(self.s[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.s[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.s.len() {
                                return Err("bad \\u".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.s[self.i..self.i + 4])
                                    .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u hex")?;
                            self.i += 4;
                            out.push(
                                char::from_u32(cp).ok_or("surrogate unsupported")?,
                            );
                        }
                        _ => return Err(format!("bad escape \\{}", esc as char)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.s[self.i..])
                        .map_err(|_| "invalid utf8")?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{
          "format": "hlo-text",
          "return_tuple": true,
          "artifacts": {
            "density_g64_k32": {
              "inputs": [{"name": "tensor", "shape": [64, 64, 64]}],
              "tile": 64, "k": 32
            }
          },
          "perf": {"vmem": 1.25e6}
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("format").unwrap().as_str(), Some("hlo-text"));
        assert_eq!(j.get("return_tuple").unwrap().as_bool(), Some(true));
        let art = j.get("artifacts").unwrap().get("density_g64_k32").unwrap();
        assert_eq!(art.get("tile").unwrap().as_usize(), Some(64));
        let shape = art.get("inputs").unwrap().idx(0).unwrap().get("shape").unwrap();
        assert_eq!(shape.idx(2).unwrap().as_usize(), Some(64));
        assert_eq!(j.get("perf").unwrap().get("vmem").unwrap().as_f64(), Some(1.25e6));
    }

    #[test]
    fn roundtrip_display_parse() {
        let doc = r#"{"a":[1,2.5,-3],"b":"x\"y\n","c":null,"d":false}"#;
        let j = Json::parse(doc).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }
}
