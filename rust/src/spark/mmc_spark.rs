//! The three-stage multimodal clustering pipeline on the Spark-like
//! engine: the same Algorithms 2–7, but with the inter-stage
//! materialisation replaced by in-memory narrow/wide transformations —
//! the paper's §7 expectation, executable.
//!
//! Stage boundaries collapse: the 6 map/reduce procedures become
//! `flat_map → group_by_key → map → flat_map → group_by_key → map →
//! group_by_key → filter`, i.e. exactly three wide shuffles and
//! everything else fused.

use crate::core::context::PolyContext;
use crate::core::pattern::Cluster;
use crate::core::tuple::NTuple;
use crate::spark::rdd::SparkContext;

/// Result mirror of `mmc::MmcResult` for the Spark-like engine.
pub struct SparkMmcResult {
    pub clusters: Vec<Cluster>,
    pub wall_ms: f64,
}

/// Run the pipeline. `theta` is the density threshold of Alg. 7.
pub fn run_mmc_spark(
    sc: &SparkContext,
    ctx: &PolyContext,
    theta: f64,
) -> SparkMmcResult {
    let timer = crate::util::stats::Timer::start();
    let tuples: Vec<NTuple> = ctx.tuples().to_vec();

    let clusters = sc
        .parallelize(tuples)
        // Alg. 2: tuple → N ⟨subrelation, entity⟩ pairs
        .flat_map("s1-map", |t: NTuple| {
            (0..t.arity())
                .map(move |k| (t.subrelation(k), t.get(k)))
                .collect::<Vec<_>>()
        })
        // Alg. 3: cumuli
        .group_by_key("s1-shuffle")
        .map("s1-cumulus", |(sub, mut es)| {
            es.sort_unstable();
            es.dedup();
            (sub, es)
        })
        // Alg. 4: expand back to generating tuples
        .flat_map("s2-map", |(sub, cumulus)| {
            let k = sub.dropped() as u32;
            cumulus
                .iter()
                .map(|&e| (NTuple::from_subrelation(&sub, e), (k, cumulus.clone())))
                .collect::<Vec<_>>()
        })
        // Alg. 5: assemble one cluster per generating tuple
        .group_by_key("s2-shuffle")
        .map("s2-assemble", |(gen, cumuli)| {
            let n = gen.arity();
            let mut comps: Vec<Option<Vec<u32>>> = vec![None; n];
            for (k, c) in cumuli {
                let slot = &mut comps[k as usize];
                if slot.is_none() {
                    *slot = Some(c);
                }
            }
            let comps: Vec<Vec<u32>> =
                comps.into_iter().map(|c| c.expect("cumulus present")).collect();
            // Alg. 6's key swap happens here: key by the cluster contents
            (comps, gen)
        })
        // Alg. 7: dedup by content, support = distinct generating tuples
        .group_by_key("s3-shuffle")
        .flat_map("s3-density", move |(comps, mut gens)| {
            gens.sort_unstable();
            gens.dedup();
            let mut c = Cluster::new(comps);
            c.support = gens.len();
            let vol = c.volume();
            (vol > 0.0 && c.support as f64 / vol >= theta).then_some(c)
        })
        .collect();

    let mut clusters = clusters;
    clusters.sort_by(|a, b| a.components.cmp(&b.components));
    SparkMmcResult { clusters, wall_ms: timer.elapsed_ms() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synthetic::{k1, k2, k3};
    use crate::mmc::{run_mmc, MmcConfig};

    fn sc() -> SparkContext {
        SparkContext::new(8, crate::util::pool::default_workers())
    }

    #[test]
    fn spark_matches_hadoop_on_k2() {
        let ctx = k2(5).inner;
        let spark = run_mmc_spark(&sc(), &ctx, 0.0);
        let hadoop = run_mmc(&ctx, &MmcConfig::default()).unwrap();
        assert_eq!(spark.clusters.len(), hadoop.clusters.len());
        for (a, b) in spark.clusters.iter().zip(&hadoop.clusters) {
            assert_eq!(a.components, b.components);
            assert_eq!(a.support, b.support);
        }
    }

    #[test]
    fn spark_matches_hadoop_on_k1_with_theta() {
        let ctx = k1(6).inner;
        let spark = run_mmc_spark(&sc(), &ctx, 0.9);
        let hadoop =
            run_mmc(&ctx, &MmcConfig { theta: 0.9, ..MmcConfig::default() }).unwrap();
        assert_eq!(spark.clusters.len(), hadoop.clusters.len());
    }

    #[test]
    fn spark_k3_single_cluster() {
        let spark = run_mmc_spark(&sc(), &k3(5), 0.0);
        assert_eq!(spark.clusters.len(), 1);
        assert_eq!(spark.clusters[0].support, 625);
    }

    #[test]
    fn stage_log_has_three_shuffles() {
        let ctx = k2(4).inner;
        let s = sc();
        let _ = run_mmc_spark(&s, &ctx, 0.0);
        let log = s.stage_log.lock().unwrap();
        let wide = log.iter().filter(|(l, _)| l.contains("shuffle")).count();
        assert_eq!(wide, 3);
    }
}
