//! CI gate: the telemetry artefacts must stay loadable. Smoke-runs the
//! CLI with `--trace-out`/`--metrics-out` and schema-validates what it
//! writes (exit 1 on any violation):
//!
//! 1. `mr --backend cluster --nodes 4` — the Chrome-trace JSONL must
//!    parse line by line with the `trace_event` keys
//!    (`name`/`ph`/`ts`/`pid`/`tid`), every `ph` must be `B` or `E`,
//!    and per-`tid` the `B`/`E` events must balance with matching names
//!    (the Perfetto duration-event contract); the metrics snapshot must
//!    carry `schema: tricluster-metrics-v1` and the `exec.cluster.*`
//!    counters the simulated cluster publishes.
//! 2. `serve-sim` — the serve plane's metrics must cover both the
//!    router (`serve.*`) and the ingest kernel underneath it (`oac.*`),
//!    including the partitioned-dedup counters (`oac.dedup.partitions`,
//!    `oac.dedup.groups`) the compactor publishes.
//! 3. `density --engine exact` — the bitset-vs-scalar dispatch counters
//!    (`density.dispatch.*`) must land.
//! 4. `density --engine exact --bitset-cap 1` — with the row-table byte
//!    cap forced to 1, the engine must take the compressed rung and
//!    prove it via `density.dispatch.compressed`.
//! 5. `serve-sim --nodes 3 --replicas 2 --query-mix 64` — the epoch
//!    query plane on the simulated cluster: the metrics must carry the
//!    snapshot-publication counter (`serve.epoch.published`), both
//!    result-cache counters (`serve.cache.hit` / `serve.cache.miss` —
//!    the 64-query mix repeats keys, so both paths must fire), and the
//!    replica-streaming counter (`serve.replica.publishes`); the trace
//!    must contain the `serve.snapshot.build` span.
//! 6. `serve-sim --tenants 3 --workload skew` — the multi-tenant pool on
//!    the shared nodes: the per-tenant counters
//!    (`serve.tenant.ingested`, `serve.tenant.compactions`) must land,
//!    the `serve.tenant.fairness_spread` gauge must be present and ≥ 1.0
//!    (it is a max/min ratio), and the trace must contain the
//!    `serve.tenant.ingest` and `serve.tenant.compact` spans.
//! 7. `serve-sim --nodes 3 --segment-dir … --resident-mib 1 --churn` —
//!    out-of-core persistence end to end on a stream whose arena
//!    footprint EXCEEDS the resident budget: the segment-log counters
//!    (`persist.segment.flush`, `persist.segment.restore`) and the
//!    spill-tier counters (`oac.arena.spill`, `oac.arena.reload`) must
//!    all land, and the trace must contain the `persist.flush` span.
//!    The CLI itself verifies the cold restore (it replays the log after
//!    the churned run and fails unless the restored index equals the
//!    live one), so this gate inherits that check through the exit code.
//!
//! Declared as a bench target (harness = false) like `check_bench`, so
//! it shares the library build; it drives the CLI through `$CARGO run`
//! (nested cargo invocations are fine — the build lock is released
//! while a bench runs) and writes everything under `target/check_trace/`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::{exit, Command};

use tricluster::obs::export::METRICS_SCHEMA;
use tricluster::util::json::Json;

fn run_cli(cargo: &str, args: &[&str]) {
    println!("check_trace: tricluster {}", args.join(" "));
    let status = Command::new(cargo)
        .args(["run", "-q", "--release", "--locked", "--bin", "tricluster", "--"])
        .args(args)
        .status()
        .unwrap_or_else(|e| {
            eprintln!("check_trace: failed to spawn {cargo} run: {e}");
            exit(1);
        });
    if !status.success() {
        eprintln!("check_trace: CLI exited with {status}");
        exit(1);
    }
}

/// Parse + validate one Chrome-trace JSONL file; returns every event's
/// name so callers can assert taxonomy coverage.
fn check_trace_file(path: &Path, failures: &mut Vec<String>) -> Vec<String> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            failures.push(format!("{}: unreadable: {e}", path.display()));
            return Vec::new();
        }
    };
    let mut names = Vec::new();
    // per-tid stacks: B pushes its name, E must match its thread's top
    let mut stacks: BTreeMap<u64, Vec<String>> = BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        let ln = i + 1;
        let ev = match Json::parse(line) {
            Ok(j) => j,
            Err(e) => {
                failures.push(format!("{}:{ln}: not JSON: {e}", path.display()));
                continue;
            }
        };
        let Some(name) = ev.get("name").and_then(Json::as_str) else {
            failures.push(format!("{}:{ln}: missing name", path.display()));
            continue;
        };
        for key in ["ts", "pid", "tid"] {
            if ev.get(key).and_then(Json::as_f64).is_none() {
                failures.push(format!(
                    "{}:{ln}: missing numeric {key}",
                    path.display()
                ));
            }
        }
        let tid = ev.get("tid").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        match ev.get("ph").and_then(Json::as_str) {
            Some("B") => stacks.entry(tid).or_default().push(name.to_string()),
            Some("E") => match stacks.entry(tid).or_default().pop() {
                Some(top) if top == name => {}
                Some(top) => failures.push(format!(
                    "{}:{ln}: E {name:?} closes {top:?} on tid {tid}",
                    path.display()
                )),
                None => failures.push(format!(
                    "{}:{ln}: E {name:?} without a B on tid {tid}",
                    path.display()
                )),
            },
            other => failures.push(format!(
                "{}:{ln}: ph {other:?} is not B/E",
                path.display()
            )),
        }
        names.push(name.to_string());
    }
    if names.is_empty() {
        failures.push(format!("{}: no events", path.display()));
    }
    for (tid, stack) in stacks {
        if !stack.is_empty() {
            failures.push(format!(
                "{}: tid {tid} left unbalanced spans: {stack:?}",
                path.display()
            ));
        }
    }
    names
}

/// Parse + schema-validate one metrics snapshot; returns the counter
/// and gauge maps (both name → value).
fn check_metrics_file(
    path: &Path,
    failures: &mut Vec<String>,
) -> (BTreeMap<String, f64>, BTreeMap<String, f64>) {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            failures.push(format!("{}: unreadable: {e}", path.display()));
            return (BTreeMap::new(), BTreeMap::new());
        }
    };
    let doc = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            failures.push(format!("{}: not JSON: {e}", path.display()));
            return (BTreeMap::new(), BTreeMap::new());
        }
    };
    if doc.get("schema").and_then(Json::as_str) != Some(METRICS_SCHEMA) {
        failures.push(format!(
            "{}: schema is not {METRICS_SCHEMA:?}",
            path.display()
        ));
    }
    let mut counters = BTreeMap::new();
    match doc.get("counters") {
        Some(Json::Obj(map)) => {
            for (k, v) in map {
                match v.as_f64() {
                    Some(n) => {
                        counters.insert(k.clone(), n);
                    }
                    None => failures.push(format!(
                        "{}: counter {k:?} is not numeric",
                        path.display()
                    )),
                }
            }
        }
        _ => failures.push(format!("{}: missing counters object", path.display())),
    }
    let mut gauges = BTreeMap::new();
    match doc.get("gauges") {
        Some(Json::Obj(map)) => {
            for (k, v) in map {
                match v.as_f64() {
                    Some(n) => {
                        gauges.insert(k.clone(), n);
                    }
                    None => failures.push(format!(
                        "{}: gauge {k:?} is not numeric",
                        path.display()
                    )),
                }
            }
        }
        _ => failures.push(format!("{}: missing gauges object", path.display())),
    }
    match doc.get("histograms") {
        Some(Json::Obj(hists)) => {
            for (k, h) in hists {
                let ok = h.get("count").and_then(Json::as_f64).is_some()
                    && h.get("sum").and_then(Json::as_f64).is_some()
                    && h.get("p50").and_then(Json::as_f64).is_some()
                    && h.get("p95").and_then(Json::as_f64).is_some()
                    && h.get("buckets")
                        .and_then(Json::as_arr)
                        .is_some_and(|b| !b.is_empty());
                if !ok {
                    failures.push(format!(
                        "{}: histogram {k:?} missing count/sum/p50/p95/buckets",
                        path.display()
                    ));
                }
            }
        }
        _ => failures.push(format!("{}: missing histograms object", path.display())),
    }
    (counters, gauges)
}

fn require_counter_prefix(
    counters: &BTreeMap<String, f64>,
    prefix: &str,
    what: &str,
    failures: &mut Vec<String>,
) {
    if !counters.keys().any(|k| k.starts_with(prefix)) {
        failures.push(format!(
            "{what}: no counter with prefix {prefix:?} (got {:?})",
            counters.keys().take(12).collect::<Vec<_>>()
        ));
    }
}

fn main() {
    let cargo = std::env::var("CARGO").unwrap_or_else(|_| "cargo".to_string());
    let out_dir = PathBuf::from("target/check_trace");
    std::fs::create_dir_all(&out_dir).expect("create target/check_trace");
    let mut failures: Vec<String> = Vec::new();

    // 1. the simulated cluster run: nested exec spans + cluster counters
    let mr_trace = out_dir.join("mr_trace.jsonl");
    let mr_metrics = out_dir.join("mr_metrics.json");
    run_cli(
        &cargo,
        &[
            "mr",
            "--dataset",
            "imdb",
            "--backend",
            "cluster",
            "--nodes",
            "4",
            "--stragglers",
            "0.2",
            "--trace-out",
            mr_trace.to_str().unwrap(),
            "--metrics-out",
            mr_metrics.to_str().unwrap(),
        ],
    );
    let names = check_trace_file(&mr_trace, &mut failures);
    if !names.iter().any(|n| n.starts_with("exec.pipeline.")) {
        failures.push("mr trace: no exec.pipeline.* span".to_string());
    }
    if !names.iter().any(|n| n.starts_with("exec.cluster.") && n.ends_with(".task")) {
        failures.push("mr trace: no per-task exec.cluster.*.task spans".to_string());
    }
    let (counters, _) = check_metrics_file(&mr_metrics, &mut failures);
    for key in ["exec.cluster.phases", "exec.cluster.tasks"] {
        if counters.get(key).copied().unwrap_or(0.0) < 1.0 {
            failures.push(format!("mr metrics: counter {key:?} missing or zero"));
        }
    }

    // 2. the serve plane: router + shard spans over the ingest kernel
    let serve_trace = out_dir.join("serve_trace.jsonl");
    let serve_metrics = out_dir.join("serve_metrics.json");
    run_cli(
        &cargo,
        &[
            "serve-sim",
            "--datasets",
            "imdb",
            "--shards",
            "4",
            "--batch",
            "512",
            "--trace-out",
            serve_trace.to_str().unwrap(),
            "--metrics-out",
            serve_metrics.to_str().unwrap(),
        ],
    );
    let serve_names = check_trace_file(&serve_trace, &mut failures);
    if !serve_names.iter().any(|n| n.starts_with("serve.")) {
        failures.push("serve trace: no serve.* spans".to_string());
    }
    let (serve_counters, _) = check_metrics_file(&serve_metrics, &mut failures);
    require_counter_prefix(&serve_counters, "serve.", "serve metrics", &mut failures);
    require_counter_prefix(&serve_counters, "oac.", "serve metrics", &mut failures);
    // the compactor's partitioned dedup always records how it was split
    for key in ["oac.dedup.partitions", "oac.dedup.groups"] {
        if serve_counters.get(key).copied().unwrap_or(0.0) < 1.0 {
            failures.push(format!("serve metrics: counter {key:?} missing or zero"));
        }
    }

    // 3. the density engine dispatch counters
    let dens_metrics = out_dir.join("density_metrics.json");
    run_cli(
        &cargo,
        &[
            "density",
            "--edge",
            "16",
            "--engine",
            "exact",
            "--metrics-out",
            dens_metrics.to_str().unwrap(),
        ],
    );
    let (dens_counters, _) = check_metrics_file(&dens_metrics, &mut failures);
    require_counter_prefix(
        &dens_counters,
        "density.dispatch.",
        "density metrics",
        &mut failures,
    );

    // 4. a 1-byte row-table cap forces the compressed rung: the ladder
    // must degrade bitset -> compressed (not scalar) and say so
    let comp_metrics = out_dir.join("density_compressed_metrics.json");
    run_cli(
        &cargo,
        &[
            "density",
            "--edge",
            "16",
            "--engine",
            "exact",
            "--bitset-cap",
            "1",
            "--metrics-out",
            comp_metrics.to_str().unwrap(),
        ],
    );
    let (comp_counters, _) = check_metrics_file(&comp_metrics, &mut failures);
    if comp_counters.get("density.dispatch.compressed").copied().unwrap_or(0.0) < 1.0 {
        failures.push(
            "capped density metrics: counter \"density.dispatch.compressed\" \
             missing or zero — the byte cap did not route to the compressed kernel"
                .to_string(),
        );
    }

    // 5. the epoch query plane: replicas + result cache on the cluster
    let query_trace = out_dir.join("query_trace.jsonl");
    let query_metrics = out_dir.join("query_metrics.json");
    run_cli(
        &cargo,
        &[
            "serve-sim",
            "--datasets",
            "imdb",
            "--shards",
            "4",
            "--batch",
            "512",
            "--nodes",
            "3",
            "--replicas",
            "2",
            "--query-mix",
            "64",
            "--trace-out",
            query_trace.to_str().unwrap(),
            "--metrics-out",
            query_metrics.to_str().unwrap(),
        ],
    );
    let query_names = check_trace_file(&query_trace, &mut failures);
    if !query_names.iter().any(|n| n == "serve.snapshot.build") {
        failures.push("query trace: no serve.snapshot.build span".to_string());
    }
    let (query_counters, _) = check_metrics_file(&query_metrics, &mut failures);
    for key in [
        "serve.epoch.published",
        "serve.cache.hit",
        "serve.cache.miss",
        "serve.replica.publishes",
    ] {
        if query_counters.get(key).copied().unwrap_or(0.0) < 1.0 {
            failures.push(format!("query metrics: counter {key:?} missing or zero"));
        }
    }

    // 6. the multi-tenant pool under an adversarial skew workload: the
    // per-tenant counters, the fairness gauge, and the tenant spans
    let tenant_trace = out_dir.join("tenant_trace.jsonl");
    let tenant_metrics = out_dir.join("tenant_metrics.json");
    run_cli(
        &cargo,
        &[
            "serve-sim",
            "--datasets",
            "imdb",
            "--shards",
            "2",
            "--nodes",
            "3",
            "--tenants",
            "3",
            "--workload",
            "skew",
            "--trace-out",
            tenant_trace.to_str().unwrap(),
            "--metrics-out",
            tenant_metrics.to_str().unwrap(),
        ],
    );
    let tenant_names = check_trace_file(&tenant_trace, &mut failures);
    for span in ["serve.tenant.ingest", "serve.tenant.compact"] {
        if !tenant_names.iter().any(|n| n == span) {
            failures.push(format!("tenant trace: no {span} span"));
        }
    }
    let (tenant_counters, tenant_gauges) =
        check_metrics_file(&tenant_metrics, &mut failures);
    for key in ["serve.tenant.ingested", "serve.tenant.compactions"] {
        if tenant_counters.get(key).copied().unwrap_or(0.0) < 1.0 {
            failures.push(format!("tenant metrics: counter {key:?} missing or zero"));
        }
    }
    match tenant_gauges.get("serve.tenant.fairness_spread") {
        Some(spread) if *spread >= 1.0 => {}
        Some(spread) => failures.push(format!(
            "tenant metrics: fairness_spread gauge {spread} below 1.0 \
             (it is a max/min ratio)"
        )),
        None => failures.push(
            "tenant metrics: gauge \"serve.tenant.fairness_spread\" missing"
                .to_string(),
        ),
    }

    // 7. out-of-core persistence under churn: a stream whose arena
    // footprint exceeds --resident-mib 1 (ml250k at 4 shards is ~3x
    // over the per-shard page budget), journalled to a segment log.
    // The CLI replays that log after the run and exits non-zero unless
    // the cold restore reproduces the live index, so run_cli already
    // enforces the equivalence half; here we require the evidence that
    // the out-of-core machinery actually engaged.
    let persist_trace = out_dir.join("persist_trace.jsonl");
    let persist_metrics = out_dir.join("persist_metrics.json");
    let persist_segments = out_dir.join("persist_segments");
    let _ = std::fs::remove_dir_all(&persist_segments);
    run_cli(
        &cargo,
        &[
            "serve-sim",
            "--datasets",
            "ml250k",
            "--shards",
            "4",
            "--nodes",
            "3",
            "--compact-every",
            "4",
            "--churn",
            "0.3",
            "--segment-dir",
            persist_segments.to_str().unwrap(),
            "--resident-mib",
            "1",
            "--trace-out",
            persist_trace.to_str().unwrap(),
            "--metrics-out",
            persist_metrics.to_str().unwrap(),
        ],
    );
    let persist_names = check_trace_file(&persist_trace, &mut failures);
    if !persist_names.iter().any(|n| n == "persist.flush") {
        failures.push("persist trace: no persist.flush span".to_string());
    }
    let (persist_counters, _) = check_metrics_file(&persist_metrics, &mut failures);
    for key in [
        // every compaction appended a delta segment...
        "persist.segment.flush",
        // ...and at least one replay decoded them (kill recovery and/or
        // the CLI's own cold-restore verification)
        "persist.segment.restore",
        // the resident budget actually bound: cold pages left the arena
        "oac.arena.spill",
        // ...and came back when the compactor walked their chains
        "oac.arena.reload",
    ] {
        if persist_counters.get(key).copied().unwrap_or(0.0) < 1.0 {
            failures.push(format!(
                "persist metrics: counter {key:?} missing or zero — \
                 the out-of-core path did not engage"
            ));
        }
    }

    if failures.is_empty() {
        println!(
            "check_trace: OK — {} mr events + {} serve events + {} query-plane \
             events + {} tenant events + {} persist events schema-valid, B/E \
             balanced per tid, metrics cover exec/serve/oac/density, the \
             epoch/cache/replica counters, the per-tenant counters + fairness \
             gauge, and the segment-log flush/restore + arena spill/reload \
             counters",
            names.len(),
            serve_names.len(),
            query_names.len(),
            tenant_names.len(),
            persist_names.len()
        );
    } else {
        for fail in &failures {
            eprintln!("check_trace: FAIL: {fail}");
        }
        exit(1);
    }
}
