//! Bench: regenerate paper Table 3 — "Three-stage MapReduce multimodal
//! clustering time, ms" (Online OAC vs M/R on IMDB, MovieLens100k, K1,
//! K2, K3).
//!
//! Quick mode by default; set `TRICLUSTER_BENCH_FULL=1` for the paper's
//! exact workload sizes. Prints the paper's reference rows alongside.

use tricluster::coordinator::{experiments, ExpConfig};

fn main() -> anyhow::Result<()> {
    let full = std::env::var("TRICLUSTER_BENCH_FULL").is_ok();
    let cfg = ExpConfig {
        full,
        nodes: 10,
        theta: 0.0,
        runs: if full { 1 } else { 3 },
        seed: 42,
    };
    eprintln!("table3 bench (full={full}) ...");
    let report = experiments::table3(&cfg)?;
    println!("{}", report.render());
    println!();
    println!("paper reference (Intel i5-2450M, Hadoop single-node emulation):");
    println!("  Online   IMDB 368 | ML100k 16,298 | K1 96,990 | K2 185,072 | K3 643,978");
    println!("  M/R      IMDB 7,124 | ML100k 14,582 | K1 37,572 | K2 61,367 | K3 102,699");
    println!("shape to reproduce: M/R loses on IMDB, wins from K1 on; gap widens with size");
    let csv = report.write_csv()?;
    eprintln!("(csv: {})", csv.display());
    Ok(())
}
