//! Context I/O: TSV triple/tuple files (the paper's input format — one
//! tuple per line, tab-separated) and the paper-style pattern output
//! (§5.2: sets in curly brackets, one set per line, clusters separated).

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{Context as _, Result};

use crate::core::context::{ManyValuedTriContext, PolyContext, TriContext};
use crate::core::pattern::Cluster;

/// Read an N-ary context from TSV (`e_1 \t e_2 \t … \t e_N` per line).
pub fn read_poly_tsv(path: &Path, arity: usize) -> Result<PolyContext> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut ctx = PolyContext::new(arity);
    for (lineno, line) in std::io::BufReader::new(file).lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split('\t').collect();
        anyhow::ensure!(
            fields.len() == arity,
            "line {}: expected {} fields, got {}",
            lineno + 1,
            arity,
            fields.len()
        );
        ctx.add_named(&fields);
    }
    Ok(ctx)
}

/// Read a triadic context from TSV.
pub fn read_tri_tsv(path: &Path) -> Result<TriContext> {
    Ok(TriContext { inner: read_poly_tsv(path, 3)? })
}

/// Read a many-valued triadic context: `g \t m \t b \t value` per line.
pub fn read_valued_tsv(path: &Path) -> Result<ManyValuedTriContext> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut ctx = ManyValuedTriContext::new();
    for (lineno, line) in std::io::BufReader::new(file).lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split('\t').collect();
        anyhow::ensure!(
            fields.len() == 4,
            "line {}: expected 4 fields, got {}",
            lineno + 1,
            fields.len()
        );
        let v: f64 = fields[3]
            .parse()
            .with_context(|| format!("line {}: bad value", lineno + 1))?;
        let ids: Vec<u32> = fields[..3]
            .iter()
            .enumerate()
            .map(|(k, n)| ctx.context.inner.interners[k].intern(n))
            .collect();
        ctx.add(ids[0], ids[1], ids[2], v);
    }
    Ok(ctx)
}

/// Write a context to TSV (inverse of `read_poly_tsv`).
pub fn write_poly_tsv(path: &Path, ctx: &PolyContext) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    for t in ctx.tuples() {
        let names: Vec<&str> = t
            .as_slice()
            .iter()
            .enumerate()
            .map(|(k, &id)| ctx.interners[k].name(id))
            .collect();
        writeln!(w, "{}", names.join("\t"))?;
    }
    Ok(())
}

/// Render one cluster in the paper's §5.2 output format:
/// ```text
/// {
/// {Toy Story (1995), Toy Story 2 (1999)}
/// {Toy, Friend}
/// {Animation, Adventure, Comedy}
/// }
/// ```
pub fn format_cluster(ctx: &PolyContext, c: &Cluster) -> String {
    let mut out = String::from("{\n");
    for (k, comp) in c.components.iter().enumerate() {
        let names = ctx.names(k, comp);
        out.push('{');
        out.push_str(&names.join(", "));
        out.push_str("}\n");
    }
    out.push('}');
    out
}

/// Write all clusters in the paper's output format.
pub fn write_clusters(path: &Path, ctx: &PolyContext, cs: &[Cluster]) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    for c in cs {
        writeln!(w, "{}", format_cluster(ctx, c))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::pattern::tricluster;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("tricluster-io-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn tsv_roundtrip() {
        let p = tmp("roundtrip.tsv");
        let mut ctx = PolyContext::new(3);
        ctx.add_named(&["One Flew Over the Cuckoo's Nest (1975)", "Nurse", "Drama"]);
        ctx.add_named(&["Star Wars V (1980)", "Princess", "Sci-Fi"]);
        write_poly_tsv(&p, &ctx).unwrap();
        let back = read_poly_tsv(&p, 3).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.interners[1].get("Princess"), Some(1));
    }

    #[test]
    fn rejects_wrong_arity() {
        let p = tmp("bad.tsv");
        std::fs::write(&p, "a\tb\n").unwrap();
        assert!(read_poly_tsv(&p, 3).is_err());
    }

    #[test]
    fn valued_tsv() {
        let p = tmp("valued.tsv");
        std::fs::write(&p, "head\tverb\tdep\t12.5\nhead\tverb\tobj\t3.0\n").unwrap();
        let ctx = read_valued_tsv(&p).unwrap();
        assert_eq!(ctx.len(), 2);
        assert_eq!(ctx.value(0, 0, 0), Some(12.5));
    }

    #[test]
    fn paper_output_format() {
        let mut ctx = PolyContext::new(3);
        ctx.add_named(&["Toy Story (1995)", "Toy", "Animation"]);
        ctx.add_named(&["Toy Story 2 (1999)", "Friend", "Adventure"]);
        let c = tricluster(vec![0, 1], vec![0, 1], vec![0, 1]);
        let s = format_cluster(&ctx.clone(), &c);
        assert!(s.starts_with("{\n{Toy Story (1995), Toy Story 2 (1999)}"));
        assert!(s.contains("{Toy, Friend}"));
        assert!(s.ends_with("}"));
    }

    #[test]
    fn skips_blank_lines() {
        let p = tmp("blank.tsv");
        std::fs::write(&p, "a\tb\tc\n\n\nd\te\tf\n").unwrap();
        assert_eq!(read_poly_tsv(&p, 3).unwrap().len(), 2);
    }
}
