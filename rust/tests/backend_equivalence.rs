//! The exec-layer invariant, tested end to end: for ANY random polyadic
//! context (arity 3 and 4), density threshold, task/worker granularity,
//! fault-injection setting, and — for the simulated cluster — straggler/
//! failure schedule, speculation mode, and placement policy, all five
//! backends — Sequential, Pooled, HadoopSim, SparkSim, ClusterSim —
//! produce the identical deduplicated cluster set (components, supports,
//! densities) as single-pass `oac::mine_online`.

mod common;

use common::{assert_same, random_ctx, sorted};
use tricluster::exec::{run_named, ExecTuning, BACKENDS};
use tricluster::oac::{mine_online, Constraints};
use tricluster::util::proptest_lite::{assert_prop, Gen};

/// Random context → every backend → exact cluster-set equality.
#[test]
fn prop_all_backends_equal_online() {
    assert_prop(48, |g: &mut Gen| {
        // small entity universes force heavy cumulus sharing — the regime
        // where assembly/dedup can go wrong
        let arity = 3 + g.usize_below(2);
        let universe = 2 + g.u32_below(8);
        let n_tuples = 1 + g.usize_below(250);
        let ctx = random_ctx(g, arity, universe, n_tuples);
        let theta = if g.bool(0.5) { 0.0 } else { g.f64() * 0.6 };
        let reference = sorted(mine_online(
            &ctx,
            &Constraints { min_density: theta, min_support: 0 },
        ));
        let tune = ExecTuning {
            workers: 1 + g.usize_below(4),
            tasks: 1 + g.usize_below(8),
            // injected task retries must be invisible in the output
            // (doubles as the ClusterSim task-failure probability)
            fault_prob: if g.bool(0.3) { 1.0 } else { 0.0 },
            seed: 0xBACC ^ n_tuples as u64,
            use_dfs: g.bool(0.2),
            // ClusterSim: randomized straggler/failure schedule,
            // speculation on/off, every placement policy, both cost
            // models — none of it may leak into the output
            nodes: 1 + g.usize_below(6),
            node_slots: 1 + g.usize_below(3),
            straggler_prob: if g.bool(0.5) { g.f64() } else { 0.0 },
            speculation: g.bool(0.5),
            placement: ["rr", "locality", "least"][g.usize_below(3)].to_string(),
            adaptive_tasks: g.bool(0.5),
            cost_ms_per_record: if g.bool(0.5) { Some(0.01) } else { None },
            // seq/pool stage 1 via the merge-based ingest kernel or the
            // generic map_reduce round — both must match the reference
            parallel_ingest: g.bool(0.5),
            // seq/pool stage 3 via the partitioned in-process grouper
            // (any partition count) or the backend group_reduce round
            dedup_partitions: g.usize_below(5),
            ..ExecTuning::default()
        };
        for backend in BACKENDS {
            let run = run_named(backend, &ctx, theta, &tune)
                .map_err(|e| format!("{backend}: {e}"))?;
            assert_same(
                &reference,
                &run.clusters,
                &format!("{backend} (arity {arity}, {n_tuples} tuples, θ={theta:.3})"),
            )?;
        }
        Ok(())
    });
}

/// All 5 backends with parallel ingest enabled must equal `mine_online`
/// — the seq/pool paths actually run the merge-based stage-1 kernel,
/// the simulated engines keep their shuffle; either way the output is
/// the reference.
#[test]
fn all_backends_equal_online_with_parallel_ingest() {
    for ctx in [
        tricluster::datasets::synthetic::k1(6).inner,
        tricluster::datasets::synthetic::k2(4).inner,
    ] {
        let reference = sorted(mine_online(&ctx, &Constraints::none()));
        for workers in [2, 4] {
            let tune = ExecTuning {
                workers,
                tasks: 4,
                parallel_ingest: true,
                ..ExecTuning::default()
            };
            for backend in BACKENDS {
                let run = run_named(backend, &ctx, 0.0, &tune).unwrap();
                assert_same(
                    &reference,
                    &run.clusters,
                    &format!("{backend} x{workers} (parallel ingest)"),
                )
                .unwrap();
            }
        }
    }
}

/// ClusterSim under an adversarial schedule — every first attempt
/// fails, half the attempts straggle 20×, speculative duplicates race —
/// must still equal `mine_online` with speculation on or off.
#[test]
fn cluster_sim_equal_under_adversarial_schedules() {
    let ctx = tricluster::datasets::synthetic::k2(5).inner;
    let reference = sorted(mine_online(&ctx, &Constraints::none()));
    for speculation in [true, false] {
        let tune = ExecTuning {
            nodes: 5,
            node_slots: 2,
            straggler_prob: 0.5,
            straggler_factor: 20.0,
            fault_prob: 1.0,
            speculation,
            cost_ms_per_record: Some(0.005),
            ..ExecTuning::default()
        };
        let run = run_named("cluster", &ctx, 0.0, &tune).unwrap();
        assert_same(
            &reference,
            &run.clusters,
            &format!("cluster adversarial, speculation={speculation}"),
        )
        .unwrap();
    }
}

/// Boundary sweep: every backend × {empty context, single tuple, dense
/// block} × {θ=0.0, θ=1.0} equals `mine_online`. θ=1.0 keeps only
/// perfectly dense clusters and θ=0.0 keeps everything — whichever side
/// of the >= the density filter sits on, reference and backend must sit
/// on the SAME side; the degenerate contexts pin the task-splitting
/// paths (0 and 1 input records across any task/worker count).
#[test]
fn edge_sweep_all_backends_at_boundary_thetas() {
    let empty = tricluster::core::context::PolyContext::new(3);
    let mut single = tricluster::core::context::PolyContext::new(3);
    single.add_ids(&[2, 5, 9]);
    let dense = tricluster::datasets::synthetic::k1(4).inner;
    for (cname, ctx) in [("empty", &empty), ("single", &single), ("k1", &dense)] {
        for theta in [0.0, 1.0] {
            let reference = sorted(mine_online(
                ctx,
                &Constraints { min_density: theta, min_support: 0 },
            ));
            if cname == "single" {
                // one tuple is one perfectly dense cluster at any θ
                assert_eq!(reference.len(), 1);
                assert_eq!(reference[0].support, 1);
            }
            if cname == "empty" {
                assert!(reference.is_empty());
            }
            for backend in BACKENDS {
                for tasks in [1, 7] {
                    let tune = ExecTuning {
                        workers: 2,
                        tasks,
                        nodes: 3,
                        node_slots: 2,
                        ..ExecTuning::default()
                    };
                    let run = run_named(backend, ctx, theta, &tune).unwrap();
                    assert_same(
                        &reference,
                        &run.clusters,
                        &format!("{backend} on {cname}, θ={theta}, tasks={tasks}"),
                    )
                    .unwrap();
                }
            }
        }
    }
}

/// The deterministic worker-sensitive backends are bit-stable across
/// worker counts on a fixed context (for ClusterSim, `workers` is the
/// REAL executor thread count — simulated placement must not leak into
/// the output either).
#[test]
fn pooled_and_spark_stable_across_worker_counts() {
    let ctx = tricluster::datasets::synthetic::k1(7).inner;
    for backend in ["pool", "spark", "cluster"] {
        let baseline = run_named(
            backend,
            &ctx,
            0.0,
            &ExecTuning { workers: 1, tasks: 3, ..ExecTuning::default() },
        )
        .unwrap();
        for workers in [2, 3, 8] {
            let run = run_named(
                backend,
                &ctx,
                0.0,
                &ExecTuning { workers, tasks: 5, ..ExecTuning::default() },
            )
            .unwrap();
            assert_same(&baseline.clusters, &run.clusters, backend).unwrap();
        }
    }
}
