//! The `Sequential` backend: the paper's single-threaded baseline (§2's
//! online/basic regime). Every phase is an in-order loop on the calling
//! thread — the reference semantics the parallel backends must match.

use anyhow::Result;

use super::backend::{group_pairs, Backend, Data, Key};

/// Single-threaded reference backend.
#[derive(Debug, Default, Clone, Copy)]
pub struct Sequential;

impl Backend for Sequential {
    fn name(&self) -> &'static str {
        "seq"
    }

    fn map_partitions<I, O, F>(&self, _label: &str, input: Vec<I>, f: F) -> Result<Vec<O>>
    where
        I: Data,
        O: Data,
        F: Fn(&I) -> Vec<O> + Sync,
    {
        let mut out = Vec::new();
        for item in &input {
            out.extend(f(item));
        }
        Ok(out)
    }

    fn group_by_key<K, V>(&self, _label: &str, pairs: Vec<(K, V)>) -> Result<Vec<(K, Vec<V>)>>
    where
        K: Key,
        V: Data,
    {
        Ok(group_pairs(pairs))
    }

    fn reduce<K, V, O, F>(&self, _label: &str, groups: Vec<(K, Vec<V>)>, f: F) -> Result<Vec<O>>
    where
        K: Key,
        V: Data,
        O: Data,
        F: Fn(&K, Vec<V>) -> Vec<O> + Sync,
    {
        let mut out = Vec::new();
        for (k, vs) in groups {
            out.extend(f(&k, vs));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_count_round() {
        let input: Vec<String> = vec!["a b a".into(), "b c".into()];
        let out = Sequential
            .map_reduce(
                "wc",
                input,
                |line: &String| {
                    line.split_whitespace().map(|w| (w.to_string(), 1u32)).collect()
                },
                super::super::backend::no_combine::<String, u32>(),
                |w: &String, counts: Vec<u32>| vec![(w.clone(), counts.len() as u32)],
            )
            .unwrap();
        assert_eq!(
            out,
            vec![("a".to_string(), 2), ("b".to_string(), 2), ("c".to_string(), 1)]
        );
    }
}
