//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access (see DESIGN.md
//! §Substitutions for the rand/rayon/clap/serde_json equivalents inside
//! the main crate), so this vendored shim provides exactly the `anyhow`
//! API surface the repo uses:
//!
//! * `anyhow::Result<T>` / `anyhow::Error` (with a readable cause chain),
//! * the `anyhow!`, `bail!`, `ensure!` macros,
//! * the `Context` extension trait on `Result<T, E: std::error::Error>`,
//!   on `Result<T, anyhow::Error>`, and on `Option<T>`.
//!
//! Error content is carried as a string chain (outermost context first);
//! `Display` joins the chain with `": "` like anyhow's `{:#}` alternate
//! form, which is what error paths here print anyway.

use std::error::Error as StdError;
use std::fmt;

/// Drop-in alias for `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chained error value. Deliberately NOT `std::error::Error`,
/// exactly like the real `anyhow::Error`, so the blanket
/// `From<E: std::error::Error>` impl below stays coherent.
pub struct Error {
    /// Outermost context first, root cause last. Never empty.
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (the `anyhow::Error::msg`
    /// entry point the macros lower to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Construct from a standard error, flattening its source chain.
    pub fn from_std<E: StdError>(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Self { chain }
    }

    /// Push an outer context frame (what `Context::context` does).
    pub fn wrap<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("chain never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Coherent because `Error` itself does not implement `std::error::Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        Error::from_std(err)
    }
}

/// Private unification of "things `.context()` can upgrade": every
/// standard error plus `Error` itself (the real anyhow's `ext::StdError`
/// trick).
pub trait IntoError: private::Sealed {
    fn into_error(self) -> Error;
}

impl<E: StdError + Send + Sync + 'static> IntoError for E {
    fn into_error(self) -> Error {
        Error::from_std(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

mod private {
    pub trait Sealed {}
    impl<E: std::error::Error + Send + Sync + 'static> Sealed for E {}
    impl Sealed for super::Error {}
}

/// `anyhow::Context`: attach context to failures of `Result` and turn
/// `None` into an error.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: IntoError> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_error().wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().wrap(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*).into())
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err(
                $crate::anyhow!(concat!("condition failed: ", stringify!($cond))).into(),
            );
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*).into());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/here")
            .with_context(|| format!("read {}", "/definitely/not/here"))?;
        Ok(s)
    }

    #[test]
    fn context_on_io_error() {
        let err = io_fail().unwrap_err();
        let text = err.to_string();
        assert!(text.starts_with("read /definitely/not/here: "), "{text}");
    }

    #[test]
    fn context_on_option() {
        let none: Option<u32> = None;
        let err = none.context("missing key").unwrap_err();
        assert_eq!(err.to_string(), "missing key");
        assert_eq!(Some(5u32).context("missing").unwrap(), 5);
    }

    #[test]
    fn context_on_anyhow_result() {
        let inner: Result<()> = Err(anyhow!("inner {}", 1));
        let err = inner.context("outer").unwrap_err();
        assert_eq!(err.to_string(), "outer: inner 1");
        assert_eq!(err.root_cause(), "inner 1");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky");
        assert_eq!(f(11).unwrap_err().to_string(), "x too big: 11");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn g() -> Result<u32> {
            let v: u32 = "not a number".parse()?;
            Ok(v)
        }
        assert!(g().is_err());
    }

    #[test]
    fn debug_prints_cause_chain() {
        let err = anyhow!("root").wrap("mid").wrap("top");
        let dbg = format!("{err:?}");
        assert!(dbg.contains("top"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("root"));
        assert_eq!(err.chain().count(), 3);
    }
}
