//! The serving layer: a sharded, incrementally-updatable, queryable
//! triclustering index — ingest → shard → merge → publish → query.
//!
//! The paper's central observation is that OAC tuples are processed
//! independently: Alg. 1 is one-pass and embarrassingly partitionable.
//! This module turns that from a batch property into a SERVICE
//! architecture (the ROADMAP north star — serve heavy query traffic
//! while the stream keeps arriving):
//!
//! * [`router`] — hash-routes incoming batches to shards with bounded
//!   in-flight batching/backpressure on [`crate::util::pool`];
//! * [`shard`] — each shard runs an incremental [`crate::oac::OnlineMiner`]
//!   over its partition and exposes epoch-tagged deltas;
//! * [`merge`] — the compactor unions per-shard partial cumuli by
//!   subrelation key (the §4.1 first reduce, made incremental) into a
//!   globally-correct index, deduplicated with the partitioned-parallel
//!   [`crate::oac::online::dedup_generated_parallel`] (bit-for-bit
//!   equal to the sequential [`crate::oac::online::dedup_generated`]
//!   the online miner keeps as its oracle);
//! * [`epoch`] — every compaction is published as an immutable
//!   [`EpochSnapshot`] through a [`SnapshotCell`] `Arc` swap, so any
//!   number of query threads read a consistent epoch while the next
//!   wave mines (reads never block writes);
//! * [`backend`] — one [`QueryBackend`] trait over the snapshot plane
//!   (`top_k` / `containing` / `entity_stats` / `stats` / `epoch`)
//!   with an `(epoch, query)`-keyed result cache; [`LocalBackend`]
//!   answers from the primary's cell;
//! * [`replica`] — read replicas on other sim nodes fed by delta
//!   streaming, staleness bounded by the retained window;
//!   [`SimRemoteBackend`] is the remote arm of the trait;
//! * [`query`] — the direct, zero-policy engine over one snapshot
//!   (top-k by density, allocation-free membership ids, aggregate
//!   stats) — what the equivalence suites compare every backend to;
//! * [`snapshot`] — restart recovery via the [`crate::persist`] binary
//!   segment log (checksummed page-frame segments, restore by bulk page
//!   adoption), with the original JSON path kept as a debug fallback
//!   behind [`SnapshotFormat::Json`];
//! * [`cluster`] — the service placed on a simulated N-node cluster:
//!   shard placement via [`crate::exec::Placement`], shuffle-cost
//!   accounting, node churn with snapshot replay, and the replica
//!   query plane modelled on the same nodes;
//! * [`tenant`] — many independent contexts (per-tenant θ, arity, and
//!   ingest quotas) multiplexed onto ONE shared node pool, placed by
//!   the tenant-salted arm of the same placement trait, with pool
//!   fairness measured (`serve.tenant.fairness_spread`) and
//!   per-tenant isolation property-tested against adversarial
//!   [`crate::workload`] scenarios.
//!
//! Correctness invariant (unit- and property-tested): for any shard
//! count, batch chunking, and compaction schedule, the compacted index
//! equals single-miner [`crate::oac::mine_online`] output — same
//! components, supports, and densities — and every published epoch
//! snapshot is internally consistent (no torn reads; see
//! `rust/tests/query_plane_equivalence.rs`).

pub mod backend;
pub mod cluster;
pub mod epoch;
pub mod merge;
pub mod query;
pub mod replica;
pub mod router;
pub mod shard;
pub mod snapshot;
pub mod tenant;

pub use backend::{LocalBackend, QueryBackend, QueryKey};
pub use cluster::{ServeSim, ServeSimConfig, ServeSimStats};
pub use epoch::{EpochSnapshot, IndexStats, SnapshotCell};
pub use merge::Compactor;
pub use query::QueryEngine;
pub use replica::{ReplicaSet, SharedReplicas, SimRemoteBackend};
pub use router::{Router, RouterStats};
pub use shard::{Shard, ShardDelta};
pub use tenant::{MultiTenantSim, TenantPoolConfig, TenantSpec, TenantStats};

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::core::pattern::Cluster;
use crate::core::tuple::NTuple;
use crate::oac::post::Constraints;
use crate::util::pool;

/// Configuration of a [`TriclusterService`].
///
/// Construct via [`Self::builder`] — the one configuration path the
/// service, the cluster sim, and the CLI share.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Relation arity (3 for triadic contexts, up to
    /// [`crate::core::tuple::MAX_ARITY`]).
    pub arity: usize,
    /// Number of shards (each one an incremental miner).
    pub shards: usize,
    /// Router high-water mark, in queued tuples: crossing it triggers a
    /// parallel drain wave (backpressure).
    pub max_pending: usize,
    /// Worker threads for drain waves (one task per shard per wave).
    pub workers: usize,
    /// Constraints applied when materialising the cluster index.
    pub constraints: Constraints,
    /// Segment-log directory for durability ([`SnapshotFormat::Segment`]
    /// snapshots land here; the spill tier uses `<dir>/spill`). `None`
    /// keeps the service memory-only.
    pub segment_dir: Option<PathBuf>,
    /// Resident arena budget in MiB, split across shards
    /// ([`crate::oac::primes::resident_pages`]); ingest beyond it spills
    /// cold page chains to disk instead of aborting. `0` = unlimited.
    pub resident_mib: usize,
    /// Snapshot encoding for [`TriclusterService::snapshot_to`].
    pub snapshot_format: SnapshotFormat,
}

/// Snapshot encoding: the binary segment log (default) or the legacy
/// pretty-printed JSON document (debug fallback — human-inspectable,
/// order-of-magnitude slower to restore because it re-ingests every
/// tuple instead of adopting pages).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SnapshotFormat {
    /// Binary segment log ([`crate::persist`]).
    #[default]
    Segment,
    /// Legacy JSON document ([`snapshot::to_json`]).
    Json,
}

impl SnapshotFormat {
    /// Parse a CLI spelling (`segment` | `json`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "segment" => Some(Self::Segment),
            "json" => Some(Self::Json),
            _ => None,
        }
    }
}

impl ServeConfig {
    /// Config with backpressure/worker defaults.
    ///
    /// Deprecated shim (positional-argument API): prefer
    /// [`Self::builder`] — see the ARCHITECTURE.md migration map.
    pub fn new(arity: usize, shards: usize) -> Self {
        Self {
            arity,
            shards: shards.max(1),
            max_pending: 64 * 1024,
            workers: pool::default_workers(),
            constraints: Constraints::none(),
            segment_dir: None,
            resident_mib: 0,
            snapshot_format: SnapshotFormat::Segment,
        }
    }

    /// Start a builder with the service defaults.
    pub fn builder() -> ServeConfigBuilder {
        ServeConfigBuilder::default()
    }

    /// Set the constraints applied at index materialisation.
    pub fn with_constraints(mut self, constraints: Constraints) -> Self {
        self.constraints = constraints;
        self
    }
}

impl ServeSimConfig {
    /// Start a builder with the sim defaults (same builder as
    /// [`ServeConfig::builder`]; finish with
    /// [`ServeConfigBuilder::build_sim`]).
    pub fn builder() -> ServeConfigBuilder {
        ServeConfigBuilder::default()
    }
}

/// A configuration the builder refuses to finish: the knob combination
/// would only fail later — as a panic, a hang, or a silently-empty
/// service — so it is rejected up front with a typed error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeConfigError {
    /// `shards == 0`: the router would have nowhere to put any tuple.
    ZeroShards,
    /// More read replicas than simulated nodes: at least two replicas
    /// would share a node, which defeats the placement model the
    /// replica plane measures.
    ReplicasExceedNodes {
        /// Requested replica count.
        replicas: usize,
        /// Simulated nodes available to host them.
        nodes: usize,
    },
    /// `retained == 0`: a replica could never catch up — the delta
    /// stream would be garbage-collected before it is read, so every
    /// read would miss the staleness bound.
    ZeroRetained,
    /// `quota == 0`: the tenant could never accept a single tuple;
    /// an always-empty tenant is a misconfiguration, not a workload.
    /// (Adversarial tests that WANT a starved tenant construct
    /// [`tenant::TenantSpec`] directly.)
    ZeroQuota,
    /// A tenant pool with no tenants.
    NoTenants,
    /// `--snapshot-format json` combined with a segment directory: the
    /// JSON fallback cannot write the segment log the directory implies,
    /// so durability would silently differ from what the flags suggest.
    FormatDirMismatch,
}

impl std::fmt::Display for ServeConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::ZeroShards => write!(f, "serve config: shards must be >= 1"),
            Self::ReplicasExceedNodes { replicas, nodes } => write!(
                f,
                "serve config: {replicas} replicas cannot be placed on \
                 {nodes} nodes (replicas must be <= nodes)"
            ),
            Self::ZeroRetained => write!(
                f,
                "serve config: retained window must be >= 1 epoch \
                 (0 would starve every replica)"
            ),
            Self::ZeroQuota => write!(
                f,
                "serve config: tenant quota must be >= 1 tuple per wave"
            ),
            Self::NoTenants => {
                write!(f, "serve config: a tenant pool needs >= 1 tenant")
            }
            Self::FormatDirMismatch => write!(
                f,
                "serve config: snapshot format `json` cannot drive a \
                 segment directory (drop --segment-dir or use `segment`)"
            ),
        }
    }
}

impl std::error::Error for ServeConfigError {}

/// One builder for the whole serve configuration surface — the
/// in-process [`ServeConfig`], the on-cluster [`ServeSimConfig`], and
/// the multi-tenant [`TenantPoolConfig`] share it, so the CLI parses
/// flags into exactly one place:
///
/// ```
/// use tricluster::serve::ServeConfig;
///
/// let cfg = ServeConfig::builder().arity(3).shards(8).build().unwrap();
/// let sim = ServeConfig::builder()
///     .arity(3)
///     .shards(8)
///     .nodes(4)
///     .replicas(2)
///     .build_sim()
///     .unwrap();
/// assert_eq!(cfg.shards, sim.shards);
/// assert_eq!(sim.replicas, 2);
/// // impossible combinations are typed errors, not downstream panics
/// assert!(ServeConfig::builder().shards(0).build().is_err());
/// assert!(ServeConfig::builder().nodes(2).replicas(3).build_sim().is_err());
/// ```
///
/// Unset knobs keep the defaults of [`ServeConfig::new`] /
/// [`ServeSimConfig::new`]; sim-only knobs (nodes, placement, churn,
/// replicas, …) are ignored by [`Self::build`]. Every finisher runs the
/// same validation ([`ServeConfigError`]) — a nonsensical knob is
/// rejected even by a finisher that would ignore it, because it always
/// indicates a caller bug.
#[derive(Debug, Clone)]
pub struct ServeConfigBuilder {
    arity: usize,
    shards: usize,
    tenants: usize,
    quota: Option<usize>,
    max_pending: Option<usize>,
    workers: Option<usize>,
    constraints: Constraints,
    nodes: usize,
    slots_per_node: Option<usize>,
    placement: Option<String>,
    batch: Option<usize>,
    route_chunk: Option<usize>,
    compact_every: Option<usize>,
    mine_ms_per_record: Option<f64>,
    route_ms_per_record: Option<f64>,
    shuffle: Option<crate::exec::cluster_sim::ShuffleModel>,
    churn: Option<crate::exec::cluster_sim::ChurnConfig>,
    source_skew: Option<f64>,
    pipeline: Option<bool>,
    rebalance: Option<bool>,
    replicas: usize,
    retained: Option<u64>,
    seed: Option<u64>,
    segment_dir: Option<PathBuf>,
    resident_mib: usize,
    snapshot_format: SnapshotFormat,
}

impl Default for ServeConfigBuilder {
    fn default() -> Self {
        Self {
            arity: 3,
            shards: 4,
            tenants: 1,
            quota: None,
            max_pending: None,
            workers: None,
            constraints: Constraints::none(),
            nodes: 1,
            slots_per_node: None,
            placement: None,
            batch: None,
            route_chunk: None,
            compact_every: None,
            mine_ms_per_record: None,
            route_ms_per_record: None,
            shuffle: None,
            churn: None,
            source_skew: None,
            pipeline: None,
            rebalance: None,
            replicas: 0,
            retained: None,
            seed: None,
            segment_dir: None,
            resident_mib: 0,
            snapshot_format: SnapshotFormat::Segment,
        }
    }
}

impl ServeConfigBuilder {
    /// Relation arity.
    pub fn arity(mut self, arity: usize) -> Self {
        self.arity = arity;
        self
    }

    /// Shard count (per tenant, for pool configs). `0` is rejected at
    /// build time ([`ServeConfigError::ZeroShards`]), not clamped.
    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    /// Tenant count for [`Self::build_pool`] (ignored by the other
    /// finishers). `0` is rejected at build time.
    pub fn tenants(mut self, tenants: usize) -> Self {
        self.tenants = tenants;
        self
    }

    /// Per-tenant ingest quota, tuples accepted per wave (pool only;
    /// unset = unlimited). `0` is rejected at build time
    /// ([`ServeConfigError::ZeroQuota`]).
    pub fn quota(mut self, quota: usize) -> Self {
        self.quota = Some(quota);
        self
    }

    /// Router backpressure high-water mark, in queued tuples.
    pub fn max_pending(mut self, max_pending: usize) -> Self {
        self.max_pending = Some(max_pending);
        self
    }

    /// Worker threads for drain waves.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers);
        self
    }

    /// Constraints applied at index materialisation.
    pub fn constraints(mut self, constraints: Constraints) -> Self {
        self.constraints = constraints;
        self
    }

    /// Simulated nodes (sim only).
    pub fn nodes(mut self, nodes: usize) -> Self {
        self.nodes = nodes.max(1);
        self
    }

    /// Worker slots per simulated node (sim only).
    pub fn slots_per_node(mut self, slots: usize) -> Self {
        self.slots_per_node = Some(slots);
        self
    }

    /// Placement policy name: `rr` | `locality` | `least` (sim only).
    pub fn placement(mut self, placement: &str) -> Self {
        self.placement = Some(placement.to_string());
        self
    }

    /// Tuples per ingest wave (sim only).
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = Some(batch);
        self
    }

    /// Tuples per route-split task within a wave (sim only).
    pub fn route_chunk(mut self, route_chunk: usize) -> Self {
        self.route_chunk = Some(route_chunk);
        self
    }

    /// Waves between compactions (sim only).
    pub fn compact_every(mut self, every: usize) -> Self {
        self.compact_every = Some(every);
        self
    }

    /// Simulated mining cost per tuple, ms (sim only).
    pub fn mine_ms_per_record(mut self, ms: f64) -> Self {
        self.mine_ms_per_record = Some(ms);
        self
    }

    /// Simulated route-split cost per tuple, ms (sim only).
    pub fn route_ms_per_record(mut self, ms: f64) -> Self {
        self.route_ms_per_record = Some(ms);
        self
    }

    /// Network cost model for moved bins (sim only).
    pub fn shuffle(mut self, shuffle: crate::exec::cluster_sim::ShuffleModel) -> Self {
        self.shuffle = Some(shuffle);
        self
    }

    /// Seeded node kill/restart mid-drain (sim only).
    pub fn churn(mut self, churn: crate::exec::cluster_sim::ChurnConfig) -> Self {
        self.churn = Some(churn);
        self
    }

    /// Source skew exponent for arrival nodes (sim only).
    pub fn source_skew(mut self, skew: f64) -> Self {
        self.source_skew = Some(skew);
        self
    }

    /// Overlap route-split of wave w+1 with mining of wave w (sim only).
    pub fn pipeline(mut self, pipeline: bool) -> Self {
        self.pipeline = Some(pipeline);
        self
    }

    /// Re-place shards by the policy at every compaction (sim only).
    pub fn rebalance(mut self, rebalance: bool) -> Self {
        self.rebalance = Some(rebalance);
        self
    }

    /// Read replicas fed by delta streaming (sim only; 0 = none).
    pub fn replicas(mut self, replicas: usize) -> Self {
        self.replicas = replicas;
        self
    }

    /// Retained window: the replica staleness bound, in epochs
    /// (sim only).
    pub fn retained(mut self, retained: u64) -> Self {
        self.retained = Some(retained);
        self
    }

    /// Seed for source-arrival and churn draws (sim only).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Segment-log directory: compactions append binary segments here
    /// and recovery replays them (CLI `--segment-dir`).
    pub fn segment_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.segment_dir = Some(dir.into());
        self
    }

    /// Resident arena budget in MiB across shards; ingest past it spills
    /// cold pages to disk (CLI `--resident-mib`; `0` = unlimited).
    pub fn resident_mib(mut self, mib: usize) -> Self {
        self.resident_mib = mib;
        self
    }

    /// Snapshot encoding (CLI `--snapshot-format`). `Json` with a
    /// segment directory set is rejected at build time
    /// ([`ServeConfigError::FormatDirMismatch`]).
    pub fn snapshot_format(mut self, format: SnapshotFormat) -> Self {
        self.snapshot_format = format;
        self
    }

    /// Reject knob combinations that could only fail later (run by
    /// every finisher).
    fn validate(&self) -> Result<(), ServeConfigError> {
        if self.shards == 0 {
            return Err(ServeConfigError::ZeroShards);
        }
        if self.replicas > self.nodes {
            return Err(ServeConfigError::ReplicasExceedNodes {
                replicas: self.replicas,
                nodes: self.nodes,
            });
        }
        if self.retained == Some(0) {
            return Err(ServeConfigError::ZeroRetained);
        }
        if self.quota == Some(0) {
            return Err(ServeConfigError::ZeroQuota);
        }
        if self.tenants == 0 {
            return Err(ServeConfigError::NoTenants);
        }
        if self.snapshot_format == SnapshotFormat::Json && self.segment_dir.is_some() {
            return Err(ServeConfigError::FormatDirMismatch);
        }
        Ok(())
    }

    /// Finish as an in-process [`ServeConfig`] (sim-only knobs are
    /// ignored).
    pub fn build(self) -> Result<ServeConfig, ServeConfigError> {
        self.validate()?;
        let mut cfg = ServeConfig::new(self.arity, self.shards);
        if let Some(v) = self.max_pending {
            cfg.max_pending = v.max(1);
        }
        if let Some(v) = self.workers {
            cfg.workers = v.max(1);
        }
        cfg.constraints = self.constraints;
        cfg.segment_dir = self.segment_dir;
        cfg.resident_mib = self.resident_mib;
        cfg.snapshot_format = self.snapshot_format;
        Ok(cfg)
    }

    /// Finish as a multi-tenant [`TenantPoolConfig`]: `tenants`
    /// identically-shaped tenants (this builder's arity, constraints,
    /// shards, quota) on a pool of `nodes` nodes with this builder's
    /// cost model. Heterogeneous mixes: push [`TenantSpec`]s onto
    /// `.tenants` afterwards, or build [`TenantPoolConfig`] directly.
    pub fn build_pool(self) -> Result<TenantPoolConfig, ServeConfigError> {
        self.validate()?;
        let mut pool = TenantPoolConfig::new(self.nodes);
        if let Some(v) = self.slots_per_node {
            pool.slots_per_node = v.max(1);
        }
        if let Some(v) = &self.placement {
            pool.placement = v.clone();
        }
        if let Some(v) = self.mine_ms_per_record {
            pool.mine_ms_per_record = v;
        }
        if let Some(v) = self.route_ms_per_record {
            pool.route_ms_per_record = v;
        }
        if let Some(v) = self.shuffle {
            pool.shuffle = v;
        }
        if let Some(v) = self.seed {
            pool.seed = v;
        }
        pool.segment_dir = self.segment_dir.clone();
        pool.resident_mib = self.resident_mib;
        for t in 0..self.tenants {
            let mut spec = TenantSpec::new(&format!("tenant-{t}"), self.arity);
            spec.constraints = self.constraints.clone();
            spec.shards = self.shards;
            if let Some(q) = self.quota {
                spec.quota = q;
            }
            pool.tenants.push(spec);
        }
        Ok(pool)
    }

    /// Finish as an on-cluster [`ServeSimConfig`].
    pub fn build_sim(self) -> Result<ServeSimConfig, ServeConfigError> {
        self.validate()?;
        let mut cfg = ServeSimConfig::new(self.arity, self.shards, self.nodes);
        if let Some(v) = self.slots_per_node {
            cfg.slots_per_node = v.max(1);
        }
        if let Some(v) = self.placement {
            cfg.placement = v;
        }
        if let Some(v) = self.batch {
            cfg.batch = v.max(1);
        }
        if let Some(v) = self.route_chunk {
            cfg.route_chunk = v.max(1);
        }
        if let Some(v) = self.compact_every {
            cfg.compact_every = v.max(1);
        }
        if let Some(v) = self.mine_ms_per_record {
            cfg.mine_ms_per_record = v;
        }
        if let Some(v) = self.route_ms_per_record {
            cfg.route_ms_per_record = v;
        }
        if let Some(v) = self.shuffle {
            cfg.shuffle = v;
        }
        if let Some(v) = self.churn {
            cfg.churn = v;
        }
        if let Some(v) = self.source_skew {
            cfg.source_skew = v;
        }
        if let Some(v) = self.pipeline {
            cfg.pipeline = v;
        }
        if let Some(v) = self.rebalance {
            cfg.rebalance = v;
        }
        cfg.replicas = self.replicas;
        if let Some(v) = self.retained {
            cfg.retained = v;
        }
        if let Some(v) = self.seed {
            cfg.seed = v;
        }
        cfg.constraints = self.constraints;
        cfg.segment_dir = self.segment_dir;
        cfg.resident_mib = self.resident_mib;
        Ok(cfg)
    }
}

/// Live service stats (router + compactor counters).
#[derive(Debug, Clone)]
pub struct ServiceStats {
    /// Shard count.
    pub shards: usize,
    /// Tuples accepted by the router so far.
    pub tuples: usize,
    /// Tuples queued but not yet mined.
    pub pending: usize,
    /// Backpressure drain waves.
    pub drains: usize,
    /// Distinct subrelation keys in the global merged index.
    pub distinct_keys: usize,
    /// Generating tuples merged into the global index.
    pub merged: usize,
    /// Cluster count of the last compaction (None if never compacted or
    /// dirty).
    pub clusters: Option<usize>,
    /// Last compacted epoch per shard.
    pub epochs: Vec<u64>,
    /// Tuples mined by each shard (load-balance view).
    pub shard_sizes: Vec<usize>,
}

/// The sharded incremental triclustering service.
///
/// Typical loop: `ingest` batches as they arrive (the router drains under
/// backpressure automatically), `compact` at serving points — which
/// publishes an immutable [`EpochSnapshot`] — then read through
/// [`Self::snapshot`] or a [`QueryBackend`] from [`Self::backend`].
/// Readers hold `Arc` snapshots, so ingest and compaction never
/// invalidate what a query thread is looking at.
/// `snapshot_to`/`restore_from` persist across restarts.
#[derive(Debug)]
pub struct TriclusterService {
    cfg: ServeConfig,
    pub(crate) router: Router,
    compactor: Compactor,
    cell: Arc<SnapshotCell>,
    /// Compactions so far — the epoch stamped on the next publication.
    epoch: u64,
}

impl TriclusterService {
    /// Service with fresh shards and an empty global index.
    pub fn new(cfg: ServeConfig) -> Self {
        let router = Router::from_config(&cfg);
        let compactor = Compactor::new(cfg.shards);
        Self { cfg, router, compactor, cell: Arc::new(SnapshotCell::new()), epoch: 0 }
    }

    /// The configuration this service runs under.
    pub fn cfg(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Route one batch into the shard queues (drains under backpressure).
    pub fn ingest(&mut self, batch: &[NTuple]) {
        self.router.submit(batch);
    }

    /// Force-drain every shard queue (e.g. end of stream).
    pub fn flush(&mut self) {
        self.router.drain();
    }

    /// Flush, merge every shard's pending delta into the global index,
    /// and publish the compacted index as the next epoch snapshot.
    /// After `compact`, reads reflect every ingested tuple.
    pub fn compact(&mut self) {
        let mut span = crate::span!("serve.compact");
        self.router.drain();
        self.compactor.pull(self.router.shards_mut());
        self.epoch += 1;
        self.cell.publish(self.compactor.snapshot(&self.cfg.constraints, self.epoch));
        span.records_out(self.compactor.generated_len() as u64);
    }

    /// The current epoch snapshot (epoch 0 and empty before the first
    /// [`Self::compact`]). Owned: hold it as long as needed — later
    /// compactions publish new snapshots without touching this one.
    pub fn snapshot(&self) -> Arc<EpochSnapshot> {
        self.cell.load()
    }

    /// The publication cell — share it with query threads (or across
    /// [`LocalBackend`]s); they keep loading consistent snapshots while
    /// this service ingests and compacts.
    pub fn snapshot_cell(&self) -> Arc<SnapshotCell> {
        Arc::clone(&self.cell)
    }

    /// An in-process [`QueryBackend`] over this service's cell, result
    /// cache enabled.
    pub fn backend(&self) -> LocalBackend {
        LocalBackend::new(self.snapshot_cell())
    }

    /// The compacted cluster index under the configured constraints.
    /// (Tuples ingested after the last `compact` are not reflected.)
    ///
    /// Deprecated shim (pre-epoch API): borrows the compactor mutably,
    /// so it still serialises reads against ingest. Prefer
    /// [`Self::snapshot`] — same clusters, owned, concurrent — see the
    /// ARCHITECTURE.md migration map.
    pub fn clusters(&mut self) -> &[Cluster] {
        self.compactor.clusters(&self.cfg.constraints)
    }

    /// A query engine over the compacted index.
    ///
    /// Deprecated shim (pre-epoch API): now returns an OWNED engine
    /// over [`Self::snapshot`] (callers that held `QueryEngine<'_>`
    /// compile unchanged — minus the borrow of the service). Prefer
    /// [`Self::backend`] for cached reads or [`Self::snapshot`]
    /// directly — see the ARCHITECTURE.md migration map.
    pub fn query(&mut self) -> QueryEngine {
        QueryEngine::from_snapshot(self.snapshot())
    }

    /// Live router + compactor counters.
    pub fn stats(&self) -> ServiceStats {
        let r = self.router.stats();
        ServiceStats {
            shards: self.router.num_shards(),
            tuples: r.tuples,
            pending: self.router.pending(),
            drains: r.drains,
            distinct_keys: self.compactor.distinct_keys(),
            merged: self.compactor.generated_len(),
            clusters: self.compactor.cached_len(),
            epochs: self.compactor.epochs().to_vec(),
            shard_sizes: self.router.shards().iter().map(Shard::len).collect(),
        }
    }

    /// Write a restart-recovery snapshot (flushes queued tuples first).
    /// Under [`SnapshotFormat::Segment`] (the default) `path` is a
    /// directory receiving one full binary segment; under
    /// [`SnapshotFormat::Json`] it is the legacy JSON document.
    pub fn snapshot_to(&mut self, path: &Path) -> anyhow::Result<()> {
        match self.cfg.snapshot_format {
            SnapshotFormat::Segment => snapshot::save_segments(self, path),
            SnapshotFormat::Json => snapshot::save(self, path),
        }
    }

    /// Rebuild a service from a snapshot written by [`Self::snapshot_to`].
    /// Dispatches on what is on disk: a directory is replayed as a
    /// segment log (restore by bulk page adoption), a file is parsed as
    /// the legacy JSON document.
    pub fn restore_from(path: &Path) -> anyhow::Result<Self> {
        if path.is_dir() {
            snapshot::load_segments(path)
        } else {
            snapshot::load(path)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oac::mine_online;

    fn sorted(mut cs: Vec<Cluster>) -> Vec<Cluster> {
        cs.sort_by(|a, b| a.components.cmp(&b.components));
        cs
    }

    #[test]
    fn sharded_equals_sequential_on_k1() {
        let ctx = crate::datasets::synthetic::k1(8).inner;
        let reference = sorted(mine_online(&ctx, &Constraints::none()));
        for shards in [1, 2, 4, 7] {
            let mut svc = TriclusterService::new(ServeConfig::new(3, shards));
            for chunk in ctx.tuples().chunks(97) {
                svc.ingest(chunk);
            }
            svc.compact();
            let got = sorted(svc.clusters().to_vec());
            assert_eq!(got.len(), reference.len(), "shards={shards}");
            for (a, b) in got.iter().zip(&reference) {
                assert_eq!(a.components, b.components);
                assert_eq!(a.support, b.support);
            }
        }
    }

    #[test]
    fn constraints_applied_at_materialisation() {
        let ctx = crate::datasets::synthetic::k2(4).inner;
        let cons = Constraints { min_density: 0.5, min_support: 2 };
        let reference = sorted(mine_online(&ctx, &cons));
        let mut svc = TriclusterService::new(
            ServeConfig::builder()
                .arity(3)
                .shards(3)
                .constraints(cons)
                .build()
                .unwrap(),
        );
        svc.ingest(ctx.tuples());
        svc.compact();
        let got = sorted(svc.clusters().to_vec());
        assert_eq!(got.len(), reference.len());
    }

    #[test]
    fn query_after_compact_sees_all_tuples() {
        let ctx = crate::datasets::synthetic::k2(3).inner; // 3 dense blocks
        let mut svc = TriclusterService::new(ServeConfig::new(3, 4));
        svc.ingest(ctx.tuples());
        svc.compact();
        let q = svc.query();
        assert_eq!(q.len(), 3);
        let top = q.top_k_by_density(1);
        assert!((top[0].support_density() - 1.0).abs() < 1e-12);
        // block 0 contains entity 0 in every modality
        assert_eq!(q.containing(0, 0).len(), 1);
        // entity of block 1 (offset 3) is in the second block's cluster only
        assert_eq!(q.containing(1, 3).len(), 1);
        let stats = svc.stats();
        assert_eq!(stats.tuples, ctx.len());
        assert_eq!(stats.pending, 0);
        assert_eq!(stats.clusters, Some(3));
    }

    #[test]
    fn stats_track_pending_and_compaction() {
        let mut svc = TriclusterService::new(ServeConfig::new(3, 2));
        svc.ingest(&[NTuple::triple(0, 0, 0), NTuple::triple(1, 1, 1)]);
        let s = svc.stats();
        assert_eq!(s.tuples, 2);
        assert_eq!(s.pending, 2, "below watermark: still queued");
        assert_eq!(s.clusters, None, "never compacted");
        svc.compact();
        let s = svc.stats();
        assert_eq!(s.pending, 0);
        assert_eq!(s.merged, 2);
        svc.clusters();
        assert_eq!(svc.stats().clusters, Some(2));
    }

    #[test]
    fn snapshot_outlives_later_compactions() {
        let ctx = crate::datasets::synthetic::k2(2).inner;
        let mut svc = TriclusterService::new(ServeConfig::new(3, 2));
        assert_eq!(svc.snapshot().epoch(), 0, "empty before first compact");
        svc.ingest(ctx.tuples());
        svc.compact();
        let first = svc.snapshot();
        assert_eq!(first.epoch(), 1);
        assert_eq!(first.stats().total_support, first.merged_tuples());
        // ingest + compact again: the held snapshot must not change
        let more: Vec<NTuple> =
            (100..110u32).map(|i| NTuple::triple(i, i, i)).collect();
        svc.ingest(&more);
        svc.compact();
        assert_eq!(first.epoch(), 1);
        assert_eq!(svc.snapshot().epoch(), 2);
        assert!(svc.snapshot().len() > first.len());
        // the deprecated query() shim reads the same published snapshot
        let q = svc.query();
        assert_eq!(q.epoch(), 2);
        assert_eq!(q.len(), svc.snapshot().len());
    }

    #[test]
    fn builder_and_positional_config_agree() {
        let a = ServeConfig::new(3, 8);
        let b = ServeConfig::builder().arity(3).shards(8).build().unwrap();
        assert_eq!(a.arity, b.arity);
        assert_eq!(a.shards, b.shards);
        assert_eq!(a.max_pending, b.max_pending);
        assert_eq!(a.workers, b.workers);
        let sim = ServeConfig::builder()
            .arity(3)
            .shards(8)
            .nodes(4)
            .replicas(2)
            .retained(1)
            .placement("rr")
            .batch(512)
            .build_sim()
            .unwrap();
        let base = ServeSimConfig::new(3, 8, 4);
        assert_eq!(sim.slots_per_node, base.slots_per_node);
        assert_eq!(sim.placement, "rr");
        assert_eq!(sim.batch, 512);
        assert_eq!(sim.replicas, 2);
        assert_eq!(sim.retained, 1);
        assert_eq!(sim.seed, base.seed);
    }

    #[test]
    fn builder_rejects_zero_shards() {
        assert_eq!(
            ServeConfig::builder().shards(0).build().unwrap_err(),
            ServeConfigError::ZeroShards
        );
        assert_eq!(
            ServeConfig::builder().shards(0).build_sim().unwrap_err(),
            ServeConfigError::ZeroShards
        );
    }

    #[test]
    fn builder_rejects_replicas_exceeding_nodes() {
        assert_eq!(
            ServeConfig::builder().nodes(2).replicas(3).build_sim().unwrap_err(),
            ServeConfigError::ReplicasExceedNodes { replicas: 3, nodes: 2 }
        );
        // replicas == nodes is the legal extreme
        assert!(
            ServeConfig::builder().nodes(2).replicas(2).build_sim().is_ok()
        );
    }

    #[test]
    fn builder_rejects_zero_retained() {
        assert_eq!(
            ServeConfig::builder().retained(0).build_sim().unwrap_err(),
            ServeConfigError::ZeroRetained
        );
        assert!(ServeConfig::builder().retained(1).build_sim().is_ok());
    }

    #[test]
    fn builder_rejects_zero_quota_and_zero_tenants() {
        assert_eq!(
            ServeConfig::builder().quota(0).build_pool().unwrap_err(),
            ServeConfigError::ZeroQuota
        );
        assert_eq!(
            ServeConfig::builder().tenants(0).build_pool().unwrap_err(),
            ServeConfigError::NoTenants
        );
        let pool = ServeConfig::builder()
            .tenants(3)
            .shards(2)
            .nodes(4)
            .quota(500)
            .build_pool()
            .unwrap();
        assert_eq!(pool.tenants.len(), 3);
        assert_eq!(pool.nodes, 4);
        assert!(pool.tenants.iter().all(|t| t.shards == 2 && t.quota == 500));
    }

    #[test]
    fn builder_persistence_knobs_flow_through_every_finisher() {
        let cfg = ServeConfig::builder()
            .segment_dir("/tmp/seglog")
            .resident_mib(64)
            .build()
            .unwrap();
        assert_eq!(cfg.segment_dir.as_deref(), Some(Path::new("/tmp/seglog")));
        assert_eq!(cfg.resident_mib, 64);
        assert_eq!(cfg.snapshot_format, SnapshotFormat::Segment);
        let sim = ServeConfig::builder()
            .segment_dir("/tmp/seglog")
            .resident_mib(64)
            .build_sim()
            .unwrap();
        assert_eq!(sim.segment_dir.as_deref(), Some(Path::new("/tmp/seglog")));
        assert_eq!(sim.resident_mib, 64);
        let pool = ServeConfig::builder()
            .segment_dir("/tmp/seglog")
            .resident_mib(64)
            .build_pool()
            .unwrap();
        assert_eq!(pool.segment_dir.as_deref(), Some(Path::new("/tmp/seglog")));
        assert_eq!(pool.resident_mib, 64);
        // JSON fallback cannot drive a segment directory
        assert_eq!(
            ServeConfig::builder()
                .snapshot_format(SnapshotFormat::Json)
                .segment_dir("/tmp/seglog")
                .build()
                .unwrap_err(),
            ServeConfigError::FormatDirMismatch
        );
        // JSON without a directory stays a valid debug fallback
        assert!(ServeConfig::builder()
            .snapshot_format(SnapshotFormat::Json)
            .build()
            .is_ok());
        assert_eq!(SnapshotFormat::parse("segment"), Some(SnapshotFormat::Segment));
        assert_eq!(SnapshotFormat::parse("json"), Some(SnapshotFormat::Json));
        assert_eq!(SnapshotFormat::parse("yaml"), None);
    }

    #[test]
    fn config_errors_display_and_convert() {
        let err = ServeConfigError::ReplicasExceedNodes { replicas: 9, nodes: 4 };
        let text = err.to_string();
        assert!(text.contains('9') && text.contains('4'), "{text}");
        // typed errors flow through anyhow call sites via `?`
        let any: anyhow::Error = ServeConfigError::ZeroShards.into();
        assert!(any.to_string().contains("shards"));
    }
}
