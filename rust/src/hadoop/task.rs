//! Task scheduling and the virtual cluster clock.
//!
//! The paper evaluates Hadoop in single-node *emulation mode* ("one can
//! estimate the performance in a real distributed environment assuming
//! that each node workload is (roughly) the same"). We go one step
//! further: every map/reduce task's wall time is recorded, and the
//! virtual clock replays the task durations onto `r` simulated workers
//! (JobTracker-style greedy list scheduling) to report the makespan a
//! real r-node cluster would see — without pretending this container has
//! r cores.

/// Greedy list-scheduling makespan: tasks (durations, ms) are assigned
/// longest-processing-time-first to the least-loaded of `workers` nodes.
/// LPT is a 4/3-approximation of optimal makespan — adequate for the
/// JobTracker comparison the paper makes.
pub fn lpt_makespan(durations: &[f64], workers: usize) -> f64 {
    assert!(workers >= 1);
    if durations.is_empty() {
        return 0.0;
    }
    let mut sorted = durations.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut loads = vec![0.0f64; workers];
    for d in sorted {
        let (idx, _) = loads
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        loads[idx] += d;
    }
    loads.iter().cloned().fold(0.0, f64::max)
}

/// FIFO makespan (tasks in submission order) — what a plain JobTracker
/// without task-size knowledge achieves; used by the skew ablation.
pub fn fifo_makespan(durations: &[f64], workers: usize) -> f64 {
    assert!(workers >= 1);
    let mut loads = vec![0.0f64; workers];
    for &d in durations {
        let (idx, _) = loads
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        loads[idx] += d;
    }
    loads.iter().cloned().fold(0.0, f64::max)
}

/// Hash-slicing makespan for the PRIOR M/R algorithm [43] (ablation A1):
/// all triples with `hash(entity) % r == j` go to reducer j, so the
/// per-reducer load is fixed by the hash — no balancing possible. Given
/// per-slice record counts and a per-record cost, returns the makespan.
pub fn sliced_makespan(slice_records: &[u64], ms_per_record: f64) -> f64 {
    slice_records
        .iter()
        .map(|&n| n as f64 * ms_per_record)
        .fold(0.0, f64::max)
}

/// Speedup curve: makespan at 1 worker / makespan at r workers, for each
/// r in `workers`.
pub fn speedups(durations: &[f64], workers: &[usize]) -> Vec<(usize, f64)> {
    let t1: f64 = durations.iter().sum();
    workers
        .iter()
        .map(|&r| {
            let tr = lpt_makespan(durations, r);
            (r, if tr > 0.0 { t1 / tr } else { 1.0 })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::assert_prop;

    #[test]
    fn single_worker_is_sum() {
        let d = [3.0, 1.0, 2.0];
        assert_eq!(lpt_makespan(&d, 1), 6.0);
        assert_eq!(fifo_makespan(&d, 1), 6.0);
    }

    #[test]
    fn perfectly_divisible() {
        let d = [1.0; 8];
        assert_eq!(lpt_makespan(&d, 4), 2.0);
    }

    #[test]
    fn lpt_beats_or_ties_fifo_on_adversarial_order() {
        // FIFO with a huge task last is bad; LPT fixes it.
        let d = [1.0, 1.0, 1.0, 1.0, 4.0];
        assert!(lpt_makespan(&d, 2) <= fifo_makespan(&d, 2));
        assert_eq!(lpt_makespan(&d, 2), 4.0);
    }

    #[test]
    fn sliced_is_max_slice() {
        assert_eq!(sliced_makespan(&[100, 50, 10], 0.5), 50.0);
    }

    #[test]
    fn empty_tasks() {
        assert_eq!(lpt_makespan(&[], 4), 0.0);
    }

    #[test]
    fn speedup_monotone() {
        let d: Vec<f64> = (0..100).map(|i| 1.0 + (i % 7) as f64).collect();
        let s = speedups(&d, &[1, 2, 4, 8]);
        assert!((s[0].1 - 1.0).abs() < 1e-9);
        for w in s.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-9, "{s:?}");
        }
    }

    #[test]
    fn prop_makespan_bounds() {
        // max(task) ≤ makespan ≤ sum(tasks); r·makespan ≥ sum
        assert_prop(128, |g| {
            let d: Vec<f64> = g.vec(|g| 0.1 + g.f64() * 10.0);
            if d.is_empty() {
                return Ok(());
            }
            let r = 1 + g.usize_below(8);
            let m = lpt_makespan(&d, r);
            let sum: f64 = d.iter().sum();
            let max = d.iter().cloned().fold(0.0, f64::max);
            if m + 1e-9 < max || m > sum + 1e-9 || (r as f64) * m + 1e-9 < sum {
                return Err(format!("bounds violated: r={r} m={m} sum={sum} max={max}"));
            }
            Ok(())
        });
    }
}
