//! Little-endian binary primitives and the segment checksum.
//!
//! Everything in a segment is little-endian and length-prefixed; cumulus
//! values are written as raw `u32` word runs framed to arena-page
//! multiples ([`crate::oac::primes::PAGE`] words), so the on-disk layout
//! mirrors [`crate::oac::primes::SetArena`]'s page pool and restore is a
//! straight word copy. The checksum chains the repo's own
//! [`mix64`] finalizer over `u64` words (xxhash-style mixing, zero new
//! dependencies) and is seeded with the byte length, so truncation
//! cannot collide with a shorter valid body.

use crate::util::hash::mix64;

/// Seed for the segment checksum chain (arbitrary odd constant).
const CHECKSUM_SEED: u64 = 0x7472_6963_5345_4721;

/// Chained-`mix64` checksum over `bytes`: the stream is consumed as
/// little-endian `u64` words (tail zero-padded), each folded through one
/// [`mix64`] round. Order-sensitive and length-sensitive.
pub fn checksum(bytes: &[u8]) -> u64 {
    let mut h = mix64(CHECKSUM_SEED ^ bytes.len() as u64);
    let mut words = bytes.chunks_exact(8);
    for w in &mut words {
        h = mix64(h ^ u64::from_le_bytes(w.try_into().expect("8-byte chunk")));
    }
    let rem = words.remainder();
    if !rem.is_empty() {
        let mut tail = [0u8; 8];
        tail[..rem.len()].copy_from_slice(rem);
        h = mix64(h ^ u64::from_le_bytes(tail));
    }
    h
}

/// Append-only little-endian encoder.
#[derive(Debug, Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    /// Empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Bytes encoded so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True before the first write.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// One byte.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Little-endian `u32`.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Little-endian `u64`.
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `f64` as its IEEE-754 bit pattern (bit-exact round trip).
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Length-prefixed UTF-8 string record.
    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// A raw run of `u32` words (caller frames/pads; see [`Self::page_run`]).
    pub fn words(&mut self, vals: &[u32]) {
        for &v in vals {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// A length-prefixed `u32` run padded with zero words to the next
    /// [`crate::oac::primes::PAGE`]-word boundary — one cumulus as raw
    /// page frames, the same framing the arena pool uses.
    pub fn page_run(&mut self, vals: &[u32]) {
        self.u32(vals.len() as u32);
        self.words(vals);
        let pad = vals.len().next_multiple_of(crate::oac::primes::PAGE) - vals.len();
        for _ in 0..pad {
            self.u32(0);
        }
    }

    /// Finish: append the checksum of everything written so far and
    /// return the framed bytes.
    pub fn finish(mut self) -> Vec<u8> {
        let sum = checksum(&self.buf);
        self.buf.extend_from_slice(&sum.to_le_bytes());
        self.buf
    }
}

/// Bounds-checked little-endian decoder; every read returns `None` past
/// the end instead of panicking (the segment layer maps `None` to
/// [`super::SegmentError::Corrupt`]).
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Reader over `buf` from the start.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes left to read.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        let slice = self.buf.get(self.pos..end)?;
        self.pos = end;
        Some(slice)
    }

    /// One byte.
    pub fn u8(&mut self) -> Option<u8> {
        Some(self.take(1)?[0])
    }

    /// Little-endian `u32`.
    pub fn u32(&mut self) -> Option<u32> {
        Some(u32::from_le_bytes(self.take(4)?.try_into().ok()?))
    }

    /// Little-endian `u64`.
    pub fn u64(&mut self) -> Option<u64> {
        Some(u64::from_le_bytes(self.take(8)?.try_into().ok()?))
    }

    /// `f64` from its bit pattern.
    pub fn f64(&mut self) -> Option<f64> {
        Some(f64::from_bits(self.u64()?))
    }

    /// Length-prefixed UTF-8 string record.
    pub fn str(&mut self) -> Option<String> {
        let n = self.u32()? as usize;
        std::str::from_utf8(self.take(n)?).ok().map(str::to_string)
    }

    /// `n` raw `u32` words.
    pub fn words(&mut self, n: usize) -> Option<Vec<u32>> {
        let raw = self.take(n.checked_mul(4)?)?;
        Some(
            raw.chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().expect("4-byte chunk")))
                .collect(),
        )
    }

    /// Inverse of [`Writer::page_run`]: length prefix, then the padded
    /// frame, truncated back to the real length.
    pub fn page_run(&mut self) -> Option<Vec<u32>> {
        let len = self.u32()? as usize;
        let framed = len.next_multiple_of(crate::oac::primes::PAGE);
        let mut vals = self.words(framed)?;
        vals.truncate(len);
        Some(vals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_roundtrip() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 3);
        w.f64(-0.125);
        w.str("modality-α");
        w.page_run(&[1, 2, 3]);
        w.page_run(&[]);
        let bytes = w.finish();
        // body + trailing checksum
        let body = &bytes[..bytes.len() - 8];
        let sum = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        assert_eq!(sum, checksum(body));
        let mut r = Reader::new(body);
        assert_eq!(r.u8(), Some(7));
        assert_eq!(r.u32(), Some(0xDEAD_BEEF));
        assert_eq!(r.u64(), Some(u64::MAX - 3));
        assert_eq!(r.f64(), Some(-0.125));
        assert_eq!(r.str().as_deref(), Some("modality-α"));
        assert_eq!(r.page_run(), Some(vec![1, 2, 3]));
        assert_eq!(r.page_run(), Some(vec![]));
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn page_run_frames_to_page_multiples() {
        use crate::oac::primes::PAGE;
        for n in [0usize, 1, PAGE - 1, PAGE, PAGE + 1, 3 * PAGE] {
            let vals: Vec<u32> = (0..n as u32).collect();
            let mut w = Writer::new();
            w.page_run(&vals);
            // 4-byte length prefix + framed words
            assert_eq!(w.len(), 4 + 4 * n.next_multiple_of(PAGE), "n={n}");
        }
    }

    #[test]
    fn reads_past_end_are_none_not_panics() {
        let mut r = Reader::new(&[1, 2, 3]);
        assert_eq!(r.u32(), None);
        assert_eq!(r.u8(), Some(1));
        assert_eq!(r.u64(), None);
        assert_eq!(r.words(9), None);
        let mut r2 = Reader::new(&[255, 255, 255, 255]);
        assert_eq!(r2.str(), None, "huge length prefix must not allocate blindly");
    }

    #[test]
    fn checksum_is_length_and_order_sensitive() {
        assert_ne!(checksum(b"ab"), checksum(b"ba"));
        assert_ne!(checksum(b"ab"), checksum(b"ab\0"));
        assert_ne!(checksum(b""), checksum(b"\0"));
        assert_eq!(checksum(b"tricluster"), checksum(b"tricluster"));
    }
}
