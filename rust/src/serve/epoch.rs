//! Epoch snapshots: the immutable, owned read view the concurrent query
//! plane is built on.
//!
//! The compactor's output was always an immutable compacted index — but
//! until PR 8 readers borrowed it through `&mut TriclusterService`, so a
//! query blocked ingest and vice versa. An [`EpochSnapshot`] instead
//! OWNS one compacted index (epoch id, clusters, and the prebuilt
//! `(modality, entity) → cluster ids` membership index) and is published
//! through a [`SnapshotCell`] as an `Arc` swap: any number of query
//! threads `load()` the current snapshot and keep reading it while the
//! next wave mines and the next compaction publishes a newer epoch.
//!
//! Consistency contract (property-tested in
//! `rust/tests/query_plane_equivalence.rs`): a loaded snapshot is
//! internally consistent — its epoch, cluster vector, membership index,
//! and [`EpochSnapshot::merged_tuples`] watermark all come from the same
//! publication, so readers never observe a torn mix of two compactions,
//! and epochs observed through one cell are monotone.

use std::sync::{Arc, RwLock};

use crate::core::pattern::Cluster;
use crate::util::hash::FxHashMap;

/// Aggregate statistics of a compacted index (whole-snapshot or
/// per-entity — see [`EpochSnapshot::stats`] /
/// [`EpochSnapshot::entity_stats`]).
#[derive(Debug, Clone, PartialEq)]
pub struct IndexStats {
    /// Clusters in the snapshot.
    pub clusters: usize,
    /// Σ support (= tuples ingested, when no constraints filter).
    pub total_support: usize,
    /// Mean support-density.
    pub mean_density: f64,
    /// Largest support-density.
    pub max_density: f64,
    /// Largest single-modality component cardinality.
    pub max_component: usize,
}

/// Stats fold over any cluster iterator (shared by the snapshot- and
/// entity-scoped stats paths; streams, no intermediate collection).
pub(crate) fn stats_of<'c>(clusters: impl Iterator<Item = &'c Cluster>) -> IndexStats {
    let mut n = 0usize;
    let mut total_support = 0usize;
    let mut mean_density = 0.0;
    let mut max_density = 0.0f64;
    let mut max_component = 0usize;
    for c in clusters {
        n += 1;
        total_support += c.support;
        let d = c.support_density();
        mean_density += d;
        max_density = max_density.max(d);
        max_component =
            max_component.max(c.components.iter().map(Vec::len).max().unwrap_or(0));
    }
    if n > 0 {
        mean_density /= n as f64;
    }
    IndexStats { clusters: n, total_support, mean_density, max_density, max_component }
}

/// One immutable published read view: a compacted cluster index at one
/// epoch, with the membership inverted index prebuilt so the hot lookup
/// ("clusters containing entity e in modality m") is a single
/// allocation-free hash probe ([`Self::containing`] returns borrowed
/// `&[u32]` ids; [`Self::resolve`] turns an id into its cluster).
#[derive(Debug)]
pub struct EpochSnapshot {
    epoch: u64,
    /// Generating tuples merged into the index when this snapshot was
    /// published — the torn-read canary: with no constraints, Σ support
    /// over `clusters` equals this exactly, for EVERY published epoch.
    merged_tuples: usize,
    clusters: Vec<Cluster>,
    /// (modality, entity id) → indices into `clusters`.
    member: FxHashMap<(u8, u32), Vec<u32>>,
}

/// The empty slice `containing` returns for unknown entities.
const NO_IDS: &[u32] = &[];

impl EpochSnapshot {
    /// Build a snapshot over an owned cluster index: constructs the
    /// inverted membership index once, then the snapshot is immutable.
    pub fn build(epoch: u64, clusters: Vec<Cluster>, merged_tuples: usize) -> Arc<Self> {
        let mut span = crate::span!("serve.snapshot.build");
        span.records_in(clusters.len() as u64);
        let mut member: FxHashMap<(u8, u32), Vec<u32>> = FxHashMap::default();
        // upper bound on distinct (modality, entity) pairs — a pair is
        // counted once per containing cluster, so overlapping snapshots
        // over-reserve; this trades transient memory for zero rehashes
        member.reserve(
            clusters
                .iter()
                .map(|c| c.components.iter().map(Vec::len).sum::<usize>())
                .sum(),
        );
        for (i, c) in clusters.iter().enumerate() {
            for (m, comp) in c.components.iter().enumerate() {
                for &e in comp {
                    member.entry((m as u8, e)).or_default().push(i as u32);
                }
            }
        }
        Arc::new(Self { epoch, merged_tuples, clusters, member })
    }

    /// The empty epoch-0 snapshot every [`SnapshotCell`] starts from.
    pub fn empty() -> Arc<Self> {
        Arc::new(Self {
            epoch: 0,
            merged_tuples: 0,
            clusters: Vec::new(),
            member: FxHashMap::default(),
        })
    }

    /// The epoch this snapshot was published at (0 = never compacted).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Generating tuples merged into the index at publication time.
    pub fn merged_tuples(&self) -> usize {
        self.merged_tuples
    }

    /// Clusters in the snapshot.
    pub fn len(&self) -> usize {
        self.clusters.len()
    }

    /// True when the snapshot has no clusters.
    pub fn is_empty(&self) -> bool {
        self.clusters.is_empty()
    }

    /// The full cluster index.
    pub fn clusters(&self) -> &[Cluster] {
        &self.clusters
    }

    /// The cluster behind an id returned by [`Self::containing`].
    ///
    /// # Panics
    /// On an id not issued by this snapshot (ids are never valid across
    /// epochs — resolve against the same snapshot that issued them).
    pub fn resolve(&self, id: u32) -> &Cluster {
        &self.clusters[id as usize]
    }

    /// Ids of every cluster whose modality-`m` component contains
    /// `entity`, in index order — allocation-free (borrows the inverted
    /// index; resolve ids via [`Self::resolve`]).
    pub fn containing(&self, modality: usize, entity: u32) -> &[u32] {
        let _span = crate::span!("serve.query.containing");
        match self.member.get(&(modality as u8, entity)) {
            Some(ids) => ids,
            None => NO_IDS,
        }
    }

    /// The k densest clusters (support-density, ties broken by support
    /// then components, so the ranking is total and deterministic).
    /// Selects the top k in O(n) before sorting only those k.
    pub fn top_k_by_density(&self, k: usize) -> Vec<&Cluster> {
        let _span = crate::span!("serve.query.top_k");
        let cs = &self.clusters;
        let mut idx: Vec<usize> = (0..cs.len()).collect();
        let k = k.min(idx.len());
        if k == 0 {
            return Vec::new();
        }
        let mut rank = |&a: &usize, &b: &usize| {
            cs[b].support_density()
                .total_cmp(&cs[a].support_density())
                .then(cs[b].support.cmp(&cs[a].support))
                .then(cs[a].components.cmp(&cs[b].components))
        };
        if k < idx.len() {
            idx.select_nth_unstable_by(k - 1, &mut rank);
            idx.truncate(k);
        }
        idx.sort_unstable_by(&mut rank);
        idx.into_iter().map(|i| &cs[i]).collect()
    }

    /// Support and density of the clusters containing `(modality,
    /// entity)` — the per-entity serving stats.
    pub fn entity_stats(&self, modality: usize, entity: u32) -> Option<IndexStats> {
        let ids = self.containing(modality, entity);
        if ids.is_empty() {
            None
        } else {
            Some(stats_of(ids.iter().map(|&i| &self.clusters[i as usize])))
        }
    }

    /// Aggregate stats over the whole snapshot.
    pub fn stats(&self) -> IndexStats {
        stats_of(self.clusters.iter())
    }
}

/// The publication point: holds the current [`EpochSnapshot`] `Arc` and
/// swaps it atomically on each compaction.
///
/// `load` is a brief read-lock plus an `Arc` clone — readers never wait
/// on mining or compaction, only on the pointer-sized swap itself, and
/// the returned `Arc` stays valid (and immutable) for as long as the
/// reader holds it, however many epochs are published meanwhile.
/// `publish` emits `serve.epoch.published` / `serve.epoch.current`.
#[derive(Debug)]
pub struct SnapshotCell {
    slot: RwLock<Arc<EpochSnapshot>>,
}

impl Default for SnapshotCell {
    fn default() -> Self {
        Self::new()
    }
}

impl SnapshotCell {
    /// A cell holding the empty epoch-0 snapshot.
    pub fn new() -> Self {
        Self { slot: RwLock::new(EpochSnapshot::empty()) }
    }

    /// The current snapshot (cheap: read-lock + `Arc` clone).
    pub fn load(&self) -> Arc<EpochSnapshot> {
        Arc::clone(&self.slot.read().expect("snapshot cell poisoned"))
    }

    /// Swap in a newer snapshot. Epochs must be non-decreasing — the
    /// monotonicity readers rely on to order what they observed.
    pub fn publish(&self, snap: Arc<EpochSnapshot>) {
        crate::obs::counter("serve.epoch.published", 1);
        crate::obs::gauge("serve.epoch.current", snap.epoch() as f64);
        let mut slot = self.slot.write().expect("snapshot cell poisoned");
        debug_assert!(
            snap.epoch() >= slot.epoch(),
            "epoch went backwards: {} -> {}",
            slot.epoch(),
            snap.epoch()
        );
        *slot = snap;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::pattern::tricluster;

    fn fixture() -> Vec<Cluster> {
        // densities: a = 1.0 (support 4 / volume 4), b = 0.5 (2/4),
        // c = 1.0 (1/1)
        let mut a = tricluster(vec![0], vec![0, 1], vec![0, 1]);
        a.support = 4;
        let mut b = tricluster(vec![1, 2], vec![0], vec![0, 1]);
        b.support = 2;
        let mut c = tricluster(vec![5], vec![5], vec![5]);
        c.support = 1;
        vec![a, b, c]
    }

    #[test]
    fn snapshot_queries_cover_topk_membership_stats() {
        let snap = EpochSnapshot::build(3, fixture(), 7);
        assert_eq!(snap.epoch(), 3);
        assert_eq!(snap.len(), 3);
        let top = snap.top_k_by_density(2);
        assert_eq!(top[0].components[0], vec![0]);
        assert_eq!(top[1].components[0], vec![5]);
        // membership returns borrowed ids; resolve maps them back
        let hits = snap.containing(1, 0);
        assert_eq!(hits.len(), 2);
        assert_eq!(snap.resolve(hits[1]).support, 2);
        assert!(snap.containing(2, 99).is_empty());
        let s = snap.stats();
        assert_eq!(s.total_support, 7);
        assert_eq!(s.max_component, 2);
        let es = snap.entity_stats(0, 5).unwrap();
        assert_eq!(es.clusters, 1);
    }

    #[test]
    fn cell_swaps_epochs_and_old_readers_keep_their_view() {
        let cell = SnapshotCell::new();
        assert_eq!(cell.load().epoch(), 0);
        cell.publish(EpochSnapshot::build(1, fixture(), 7));
        let old = cell.load();
        cell.publish(EpochSnapshot::build(2, Vec::new(), 7));
        // the epoch-1 reader still sees epoch-1 contents after the swap
        assert_eq!(old.epoch(), 1);
        assert_eq!(old.len(), 3);
        assert_eq!(cell.load().epoch(), 2);
        assert!(cell.load().is_empty());
    }

    #[test]
    fn concurrent_loads_see_consistent_snapshots() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let cell = Arc::new(SnapshotCell::new());
        let stop = Arc::new(AtomicBool::new(false));
        let readers: Vec<_> = (0..2)
            .map(|_| {
                let (cell, stop) = (Arc::clone(&cell), Arc::clone(&stop));
                std::thread::spawn(move || {
                    let mut last = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let s = cell.load();
                        // the publication invariant: epoch e carries
                        // exactly e fixture copies — any mix of two
                        // publications would break it
                        assert_eq!(s.len(), s.epoch() as usize * 3);
                        assert!(s.epoch() >= last, "epoch went backwards");
                        last = s.epoch();
                    }
                })
            })
            .collect();
        for e in 1..=50u64 {
            let mut cs = Vec::new();
            for _ in 0..e {
                cs.extend(fixture());
            }
            cell.publish(EpochSnapshot::build(e, cs, 0));
        }
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            r.join().expect("reader observed a torn snapshot");
        }
    }
}
