//! Ablation experiments (DESIGN.md A1–A3): the design choices the paper
//! argues for, made measurable.

use anyhow::Result;

use crate::coordinator::report::Report;
use crate::core::context::TriContext;
use crate::core::pattern::Cluster;
use crate::datasets;
use crate::density::{DensityEngine, ExactEngine, MonteCarloEngine, XlaEngine};
use crate::hadoop::task::sliced_makespan;
use crate::mmc::{run_mmc, MmcConfig};
use crate::oac::{mine_online, Constraints};
use crate::row;
use crate::util::hash::fxhash;
use crate::util::stats::Timer;
use crate::util::table::fmt_ms;

/// A1 — hash-slicing skew of the prior M/R algorithm [43] vs the
/// replication-based three-stage algorithm (paper §1).
///
/// The prior algorithm sliced input triples by `hash(e_k) % r` for a
/// chosen modality k and ran the online algorithm per slice. When the
/// modality has few distinct values (IMDB's 20 genres), slices are
/// skewed or even empty; the three-stage algorithm's many small tasks
/// balance instead.
pub fn partition_skew(r_nodes: usize) -> Result<Report> {
    let ctx = datasets::imdb(&datasets::ImdbParams::default());
    let mut report = Report::new(
        "Ablation A1: hash-slicing skew vs task-balanced 3-stage",
        vec![
            "Strategy".into(),
            "busy nodes".into(),
            "max slice".into(),
            "mean slice".into(),
            "imbalance".into(),
            format!("makespan ms ({r_nodes} nodes, 1µs/rec)"),
        ],
    );
    let names = ["objects (movies)", "attributes (tags)", "conditions (genres)"];
    for (k, label) in names.iter().enumerate() {
        let mut slices = vec![0u64; r_nodes];
        for t in ctx.triples() {
            slices[(fxhash(&t.get(k)) % r_nodes as u64) as usize] += 1;
        }
        let busy = slices.iter().filter(|&&s| s > 0).count();
        let max = *slices.iter().max().unwrap();
        let mean = ctx.len() as f64 / r_nodes as f64;
        report.push(row![
            format!("[43] slice by {label}"),
            busy,
            max,
            format!("{mean:.0}"),
            format!("{:.2}", max as f64 / mean.max(1e-9)),
            format!("{:.1}", sliced_makespan(&slices, 0.001))
        ]);
    }
    // our 3-stage pipeline partitions by SUBRELATION hash: the key space
    // is |I|·N fine-grained keys instead of one modality's entity set, so
    // reducer loads stay near-uniform even when a modality is tiny
    let mut parts = vec![0u64; r_nodes];
    for t in ctx.triples() {
        for k in 0..3 {
            let key = crate::hadoop::record::Record::to_bytes(&t.subrelation(k));
            parts[(fxhash(&key) % r_nodes as u64) as usize] += 1;
        }
    }
    let busy = parts.iter().filter(|&&s| s > 0).count();
    let max = *parts.iter().max().unwrap();
    let mean = parts.iter().sum::<u64>() as f64 / r_nodes as f64;
    report.push(row![
        "3-stage M/R subrelation keys (this paper)",
        busy,
        max,
        format!("{mean:.0}"),
        format!("{:.2}", max as f64 / mean.max(1e-9)),
        format!("{:.1}", sliced_makespan(&parts, 0.001))
    ]);
    // sanity: the pipeline actually runs and balances across many tasks
    let res = run_mmc(
        &ctx.inner,
        &MmcConfig {
            map_tasks: r_nodes * 4,
            reduce_tasks: r_nodes * 4,
            ..MmcConfig::default()
        },
    )?;
    let total_tasks: usize =
        res.stages.iter().map(|s| s.map_task_ms.len() + s.reduce_task_ms.len()).sum();
    report.push(row![
        format!("3-stage M/R measured ({total_tasks} tasks)"),
        r_nodes,
        "-",
        "-",
        "-",
        format!("{:.1}", res.makespan_ms(r_nodes))
    ]);
    Ok(report)
}

/// A3 — duplicate tolerance under task retries: output must be invariant
/// and the overhead bounded (paper §5.1's rationale for K1–K3).
pub fn fault_injection() -> Result<Report> {
    let ctx = datasets::k2(16).inner;
    let mut report = Report::new(
        "Ablation A3: task-retry duplicate injection",
        vec![
            "fault prob".into(),
            "M/R wall ms".into(),
            "retries".into(),
            "dup inputs".into(),
            "#clusters".into(),
            "output invariant".into(),
        ],
    );
    let base = run_mmc(&ctx, &MmcConfig::default())?;
    for &p in &[0.0, 0.25, 0.5, 1.0] {
        let cfg = MmcConfig { fault_prob: p, seed: 0xFA17, ..MmcConfig::default() };
        let res = run_mmc(&ctx, &cfg)?;
        let retries: u64 = res
            .stages
            .iter()
            .map(|s| s.counters.get(crate::hadoop::counters::names::TASK_RETRIES))
            .sum();
        let dups: u64 = res
            .stages
            .iter()
            .map(|s| {
                s.counters.get(crate::hadoop::counters::names::DUPLICATE_INPUTS)
            })
            .sum();
        let same = res.clusters.len() == base.clusters.len()
            && res
                .clusters
                .iter()
                .zip(base.clusters.iter())
                .all(|(a, b)| a.components == b.components && a.support == b.support);
        report.push(row![
            format!("{p:.2}"),
            fmt_ms(res.wall_ms),
            retries,
            dups,
            res.clusters.len(),
            if same { "yes" } else { "NO — BUG" }
        ]);
    }
    Ok(report)
}

/// A4 — DFS materialisation vs in-memory intermediates and the stage-1
/// map-side combiner: the two engine knobs §7's "further development
/// with Apache Spark" motivates. Spark's core advantage over Hadoop for
/// this pipeline is skipping the replicated on-"disk" materialisation
/// between stages; the combiner trades map CPU for shuffle bytes.
pub fn dfs_vs_memory() -> Result<Report> {
    let ctx = datasets::movielens(&datasets::MovielensParams::with_tuples(50_000));
    let mut report = Report::new(
        "Ablation A4: intermediates — DFS (Hadoop) vs memory (Spark-like) vs combiner",
        vec![
            "Mode".into(),
            "M/R wall ms".into(),
            "shuffle MiB".into(),
            "replicated MiB".into(),
            "#clusters".into(),
        ],
    );
    let base = MmcConfig { fault_prob: 0.3, seed: 0xA4, ..MmcConfig::default() };
    let mut reference = None;
    for (label, cfg) in [
        ("Hadoop-style: DFS x3 + no combiner", base.clone()),
        (
            "Hadoop-style + stage-1 combiner",
            MmcConfig { combiner: true, ..base.clone() },
        ),
        (
            "Hadoop engine, in-memory intermediates",
            MmcConfig { use_dfs: false, ..base.clone() },
        ),
    ] {
        let res = run_mmc(&ctx, &cfg)?;
        let repl: u64 = res
            .stages
            .iter()
            .map(|s| {
                s.counters.get(crate::hadoop::counters::names::REPLICATED_BYTES)
            })
            .sum();
        if let Some(n) = reference {
            anyhow::ensure!(res.clusters.len() == n, "mode changed output");
        } else {
            reference = Some(res.clusters.len());
        }
        report.push(row![
            label,
            fmt_ms(res.wall_ms),
            res.shuffle_bytes() >> 20,
            repl >> 20,
            res.clusters.len()
        ]);
    }
    // the actual Spark-like RDD engine (spark::): fused narrow stages,
    // three in-memory wide shuffles, no Writable encode/decode at all
    let sc = crate::spark::SparkContext::new(
        base.map_tasks,
        base.executor_threads,
    );
    let spark = crate::spark::run_mmc_spark(&sc, &ctx, base.theta);
    anyhow::ensure!(
        Some(spark.clusters.len()) == reference,
        "spark engine changed output"
    );
    report.push(row![
        "Spark-like RDD engine (spark::)",
        fmt_ms(spark.wall_ms),
        "-",
        0,
        spark.clusters.len()
    ]);
    Ok(report)
}

/// A2 — density engines: exact counting vs the XLA/Pallas tile kernel vs
/// Monte-Carlo estimation, on the clusters the online miner produces.
/// Requires `make artifacts`; returns a stub report when absent.
pub fn density_engines() -> Result<Report> {
    let mut report = Report::new(
        "Ablation A2: density engines (exact vs XLA tile kernel vs MC)",
        vec![
            "Engine".into(),
            "clusters".into(),
            "time ms".into(),
            "max |err| vs exact".into(),
        ],
    );
    // K1(48) fits a single 64³ tile; its 3n+1 clusters have mixed volumes
    let tri = datasets::synthetic::k1(48);
    let clusters = mine_online(&tri.inner, &Constraints::none());
    let ctx: &TriContext = &tri;

    let run = |eng: &mut dyn DensityEngine,
               ctx: &TriContext,
               cs: &[Cluster]|
     -> (Vec<f64>, f64) {
        let t = Timer::start();
        let d = eng.densities(ctx, cs);
        (d, t.elapsed_ms())
    };

    let mut exact = ExactEngine::default();
    let (d_exact, t_exact) = run(&mut exact, ctx, &clusters);
    report.push(row!["exact", clusters.len(), fmt_ms(t_exact), "0"]);

    let mut mc = MonteCarloEngine::host(1024, 99);
    let (d_mc, t_mc) = run(&mut mc, ctx, &clusters);
    let err_mc = d_exact
        .iter()
        .zip(&d_mc)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    report.push(row![
        "monte-carlo (1024 host)",
        clusters.len(),
        fmt_ms(t_mc),
        format!("{err_mc:.4}")
    ]);

    if crate::runtime::artifacts_available() {
        let rt = crate::runtime::Runtime::load(&crate::runtime::default_artifact_dir())?;
        let mut xla = XlaEngine::new(&rt, 48, clusters.len())?;
        let (d_xla, t_xla) = run(&mut xla, ctx, &clusters);
        let err = d_exact
            .iter()
            .zip(&d_xla)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        report.push(row![
            "xla-pallas (64³ tile)",
            clusters.len(),
            fmt_ms(t_xla),
            format!("{err:.2e}")
        ]);
        let mut mcx = MonteCarloEngine::with_artifact(&rt, "mc_g64_s1024", 99)?;
        let (d_mcx, t_mcx) = run(&mut mcx, ctx, &clusters);
        let err = d_exact
            .iter()
            .zip(&d_mcx)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        report.push(row![
            "monte-carlo (1024 xla)",
            clusters.len(),
            fmt_ms(t_mcx),
            format!("{err:.4}")
        ]);
    } else {
        report.push(row!["xla-pallas", "-", "-", "artifacts not built"]);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skew_report_shows_imbalance() {
        let r = partition_skew(10).unwrap();
        assert_eq!(r.rows.len(), 6);
        // slicing by genres (20 distinct values over 10 nodes) must be
        // visibly imbalanced: imbalance factor > 1.2
        let genre_row = &r.rows[3];
        let imbalance: f64 = genre_row[4].parse().unwrap();
        assert!(imbalance > 1.2, "imbalance={imbalance}");
    }

    #[test]
    fn fault_report_invariant() {
        let r = fault_injection().unwrap();
        for row in &r.rows[1..] {
            assert_eq!(row[5], "yes");
        }
    }
}
