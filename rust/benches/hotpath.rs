//! Micro-benchmarks of the Layer-3 hot paths. Writes
//! `BENCH_hotpath.json` (repo root), gated by `ci/check_bench.rs`
//! against `ci/bench_baseline.json`:
//!
//!   * online OAC ingest, sequential vs merge-based parallel
//!     (`PrimeStore::par_add_batch`) on the dense K1 context — the gate
//!     enforces an absolute sequential floor AND parallel ≥ sequential
//!     (the sequential path itself runs the SIMD-width batched probe
//!     pipeline, verified against the scalar `add` loop);
//!   * fingerprint dedup over the ingested state: the auto path, then
//!     the sequential oracle vs the partitioned parallel dedup
//!     (`dedup_generated_parallel`) — gate: parallel ≥
//!     `min_dedup_parallel_ratio` × sequential, bit-equal required;
//!   * exact density, scalar hash-probe oracle vs the bitset
//!     (`density::densities_bitset`) kernel, plus the compressed
//!     (array/bitmap/run) kernel on a context whose flat row table
//!     EXCEEDS `BITSET_MAX_BYTES` — with an obs-counter proof that the
//!     exact engine actually dispatches to the compressed rung there;
//!   * record codec + shuffle sort/group (reported, not gated);
//!   * observability overhead: the instrumented ingest with telemetry
//!     disabled vs a hand-inlined no-telemetry build of the same kernel
//!     (gate: within `min_obs_disabled_ratio`, 3% by policy), and with
//!     telemetry enabled (gate: `min_obs_enabled_ratio`).
//!
//! Doubles as an equivalence gate, enforced at the source: the parallel
//! ingest must export cumuli identical to sequential ingest, and the
//! bitset densities must equal the scalar oracle exactly — the bench
//! aborts otherwise, so CI's smoke run fails on divergence.

use std::collections::BTreeMap;

use tricluster::core::tuple::NTuple;
use tricluster::datasets::synthetic::k1;
use tricluster::datasets::{movielens, MovielensParams};
use tricluster::density::{densities_bitset, densities_scalar};
use tricluster::hadoop::record::Record;
use tricluster::oac::primes::{PrimeStore, SetIds};
use tricluster::oac::{mine_online, Constraints, OnlineMiner};
use tricluster::util::json::Json;
use tricluster::util::pool;
use tricluster::util::stats::{measure_ms, Summary};

fn report(name: &str, unit_per_run: f64, unit: &str, samples: &[f64]) -> f64 {
    let s = Summary::of(samples);
    let rate = unit_per_run / (s.median / 1e3);
    println!(
        "{name:<30} median {m:>9.2} ms  (p95 {p:>9.2})  => {rate:>12.0} {unit}/s",
        m = s.median,
        p = s.p95,
    );
    rate
}

fn median_ms(samples: &[f64]) -> f64 {
    Summary::of(samples).median
}

fn main() {
    let full = std::env::var("TRICLUSTER_BENCH_FULL").is_ok();
    let workers = pool::default_workers();
    let mut doc: BTreeMap<String, Json> = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("hotpath".into()));
    doc.insert("full".to_string(), Json::Bool(full));
    doc.insert("workers".to_string(), Json::Num(workers as f64));

    // ── ingest: sequential vs merge-based parallel, dense K1 regime ──
    let k1_n = if full { 80 } else { 48 };
    let ctx = k1(k1_n);
    let tuples = ctx.triples().to_vec();
    let n = tuples.len();
    println!("ingest context: K1({k1_n}) = {n} triples, {workers} workers\n");

    // equivalence gate before timing: the batched probe pipeline and the
    // parallel ingest must both export the exact cumuli (and per-tuple
    // set ids) the scalar `add` loop builds
    {
        let mut seq = PrimeStore::new(3);
        let seq_ids: Vec<SetIds> = tuples.iter().map(|t| seq.add(t)).collect();
        let mut batched = PrimeStore::new(3);
        let batched_ids = batched.add_batch(&tuples);
        assert_eq!(
            batched_ids, seq_ids,
            "batched probing diverged from the scalar add loop"
        );
        assert_eq!(batched.cumuli(), seq.cumuli(), "batched cumuli diverged");
        let mut par = PrimeStore::new(3);
        par.par_add_batch(&tuples, workers.max(2));
        assert_eq!(
            seq.cumuli(),
            par.cumuli(),
            "parallel ingest diverged from sequential"
        );
    }
    // keys only survive to the JSON when the asserts above did not abort
    doc.insert("batched_matches_scalar".to_string(), Json::Bool(true));

    let seq_samples = measure_ms(1, 7, || {
        let mut miner = OnlineMiner::new(3);
        miner.add_batch(&tuples);
        std::hint::black_box(miner.len());
    });
    let seq_rate = report("ingest sequential (K1)", n as f64, "tuples", &seq_samples);

    let par_samples = measure_ms(1, 7, || {
        let mut miner = OnlineMiner::new(3);
        miner.par_add_batch(&tuples, workers);
        std::hint::black_box(miner.len());
    });
    let par_rate = report("ingest parallel (K1)", n as f64, "tuples", &par_samples);
    let ratio = median_ms(&seq_samples) / median_ms(&par_samples);
    println!("{:<30} {ratio:>32.2}x vs sequential", "parallel speedup");

    doc.insert("ingest_tuples".to_string(), Json::Num(n as f64));
    doc.insert("ingest_seq_tuples_per_s".to_string(), Json::Num(seq_rate));
    doc.insert("ingest_par_tuples_per_s".to_string(), Json::Num(par_rate));
    doc.insert("parallel_vs_sequential".to_string(), Json::Num(ratio));
    doc.insert("parallel_matches_sequential".to_string(), Json::Bool(true));

    // ── dedup over the ingested state (cached sorted cumuli) ──
    let mut miner = OnlineMiner::new(3);
    miner.add_batch(&tuples);
    let dedup_samples = measure_ms(1, 5, || {
        let out = miner.dedup_and_filter(&Constraints::none());
        std::hint::black_box(out.len());
    });
    let dedup_rate = report("dedup (memoized sets)", n as f64, "tuples", &dedup_samples);
    doc.insert("dedup_tuples_per_s".to_string(), Json::Num(dedup_rate));

    // ── dedup: sequential oracle vs partitioned parallel, same state ──
    // (the arena is sealed by the dedup_and_filter runs above)
    use tricluster::oac::{dedup_generated, dedup_generated_parallel};
    let arena = &miner.primes().arena;
    let generated = miner.generated();
    let cons = Constraints::none();
    let dedup_workers = workers.max(2);
    let dedup_partitions = dedup_workers.min(16);
    {
        let seq = dedup_generated(arena, generated, &cons);
        let par = dedup_generated_parallel(
            arena,
            generated,
            &cons,
            dedup_workers,
            dedup_partitions,
        );
        assert_eq!(seq.len(), par.len(), "parallel dedup changed the cluster count");
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.components, b.components, "parallel dedup reordered/changed");
            assert_eq!(a.support, b.support, "parallel dedup changed a support");
        }
    }
    doc.insert("dedup_parallel_matches_sequential".to_string(), Json::Bool(true));
    let dedup_seq_samples = measure_ms(1, 5, || {
        std::hint::black_box(dedup_generated(arena, generated, &cons).len());
    });
    let dedup_seq_rate =
        report("dedup sequential oracle", n as f64, "tuples", &dedup_seq_samples);
    let dedup_par_samples = measure_ms(1, 5, || {
        std::hint::black_box(
            dedup_generated_parallel(arena, generated, &cons, dedup_workers, dedup_partitions)
                .len(),
        );
    });
    let dedup_par_rate =
        report("dedup parallel (partitioned)", n as f64, "tuples", &dedup_par_samples);
    let dedup_ratio = median_ms(&dedup_seq_samples) / median_ms(&dedup_par_samples);
    println!("{:<30} {dedup_ratio:>32.2}x vs sequential", "dedup parallel speedup");
    doc.insert("dedup_seq_tuples_per_s".to_string(), Json::Num(dedup_seq_rate));
    doc.insert("dedup_par_tuples_per_s".to_string(), Json::Num(dedup_par_rate));
    doc.insert("dedup_par_vs_seq".to_string(), Json::Num(dedup_ratio));

    // ── exact density: scalar oracle vs bitset kernel ──
    let d_n = if full { 56 } else { 32 };
    let dctx = k1(d_n);
    let clusters = mine_online(&dctx.inner, &Constraints::none());
    let cells: f64 = clusters.iter().map(|c| c.volume()).sum();
    println!(
        "\ndensity context: K1({d_n}), {} clusters, {cells:.0} cuboid cells",
        clusters.len()
    );
    let scalar = densities_scalar(&dctx, &clusters);
    let bitset = densities_bitset(&dctx, &clusters, usize::MAX)
        .expect("K1 row table fits any cap");
    assert_eq!(scalar, bitset, "bitset densities diverged from the scalar oracle");

    let scalar_samples = measure_ms(1, 3, || {
        std::hint::black_box(densities_scalar(&dctx, &clusters).len());
    });
    let scalar_rate = report("density scalar oracle", cells, "cells", &scalar_samples);
    let bitset_samples = measure_ms(1, 5, || {
        std::hint::black_box(
            densities_bitset(&dctx, &clusters, usize::MAX).unwrap().len(),
        );
    });
    let bitset_rate = report("density bitset kernel", cells, "cells", &bitset_samples);
    doc.insert("density_cells".to_string(), Json::Num(cells));
    doc.insert("density_scalar_cells_per_s".to_string(), Json::Num(scalar_rate));
    doc.insert("density_bitset_cells_per_s".to_string(), Json::Num(bitset_rate));
    doc.insert(
        "bitset_vs_scalar".to_string(),
        Json::Num(median_ms(&scalar_samples) / median_ms(&bitset_samples)),
    );
    doc.insert("bitset_matches_scalar".to_string(), Json::Bool(true));

    // warm-vs-cold engine: the revision-keyed row-table cache should make
    // repeated calls against an unchanged context cheaper than rebuilding
    // every call (reported, not gated — small contexts amortise fast)
    {
        use tricluster::density::{DensityEngine, ExactEngine};
        let cold_samples = measure_ms(1, 5, || {
            let mut e = ExactEngine::default();
            std::hint::black_box(e.densities(&dctx, &clusters).len());
        });
        let mut warm_engine = ExactEngine::default();
        warm_engine.densities(&dctx, &clusters); // prime the cache
        let warm_samples = measure_ms(1, 5, || {
            std::hint::black_box(warm_engine.densities(&dctx, &clusters).len());
        });
        let warm_ratio = median_ms(&cold_samples) / median_ms(&warm_samples);
        println!("{:<30} {warm_ratio:>32.2}x vs cold rebuild", "row-cache warm speedup");
        doc.insert("density_engine_warm_vs_cold".to_string(), Json::Num(warm_ratio));
    }

    // ── compressed kernel: a context DENSER than the flat-table cap ──
    // One far-flung (g, m) pair inflates the flat grid to ~1 GB —
    // BitRows::build must refuse it, and the exact engine must serve the
    // context through the compressed rows, not the O(volume) scalar loop.
    use tricluster::density::exact::BITSET_MAX_BYTES;
    use tricluster::density::{densities_compressed, BitRows};
    let mut dense = k1(24);
    dense.add(11_000, 11_000, 0);
    assert!(
        BitRows::build(&dense, BITSET_MAX_BYTES).is_none(),
        "dense context unexpectedly fits the flat-table cap"
    );
    doc.insert("dense_over_bitset_cap".to_string(), Json::Bool(true));
    let dclusters = mine_online(&dense.inner, &Constraints::none());
    let dense_cells: f64 = dclusters.iter().map(|c| c.volume()).sum();
    println!(
        "\ndense context: K1(24) + stray (11000, 11000, 0): {} clusters, \
         {dense_cells:.0} cells, flat table over the {BITSET_MAX_BYTES}-byte cap",
        dclusters.len()
    );
    let dense_scalar = densities_scalar(&dense, &dclusters);
    assert_eq!(
        densities_compressed(&dense, &dclusters),
        dense_scalar,
        "compressed densities diverged from the scalar oracle"
    );
    doc.insert("compressed_matches_scalar".to_string(), Json::Bool(true));
    // dispatch proof: with telemetry on, the engine must take the
    // compressed rung on this context (and still answer exactly)
    {
        use tricluster::density::{DensityEngine, ExactEngine};
        use tricluster::obs;
        obs::reset();
        obs::enable();
        let engine_out = ExactEngine::default().densities(&dense, &dclusters);
        let snap = obs::snapshot();
        obs::disable();
        obs::reset();
        assert_eq!(engine_out, dense_scalar, "engine diverged on the dense context");
        let compressed_hits = snap
            .counters
            .get("density.dispatch.compressed")
            .copied()
            .unwrap_or(0);
        assert!(
            compressed_hits >= 1,
            "exact engine did not dispatch to the compressed kernel \
             (counters: {:?})",
            snap.counters
        );
    }
    let compressed_samples = measure_ms(1, 5, || {
        std::hint::black_box(densities_compressed(&dense, &dclusters).len());
    });
    let compressed_rate = report(
        "density compressed kernel",
        dense_cells,
        "cells",
        &compressed_samples,
    );
    doc.insert("density_compressed_cells_per_s".to_string(), Json::Num(compressed_rate));

    // ── record codec + shuffle sort/group (reported only) ──
    let mcount = if full { 500_000 } else { 200_000 };
    let mctx = movielens(&MovielensParams::with_tuples(mcount));
    let mtuples = mctx.tuples().to_vec();
    let mn = mtuples.len();
    println!("\ncodec/shuffle stream: movielens {mn} 4-ary tuples");
    let codec_samples = measure_ms(1, 5, || {
        let mut buf = Vec::with_capacity(mtuples.len() * 20);
        for t in &mtuples {
            t.encode(&mut buf);
        }
        let mut slice = buf.as_slice();
        let mut count = 0usize;
        while !slice.is_empty() {
            std::hint::black_box(NTuple::decode(&mut slice));
            count += 1;
        }
        assert_eq!(count, mtuples.len());
    });
    let codec_rate = report("record codec roundtrip", mn as f64, "records", &codec_samples);
    doc.insert("codec_records_per_s".to_string(), Json::Num(codec_rate));

    let pairs: Vec<(Vec<u8>, Vec<u8>)> = mtuples
        .iter()
        .map(|t| (t.subrelation(0).to_bytes(), t.get(0).to_bytes()))
        .collect();
    let shuffle_samples = measure_ms(1, 5, || {
        let mut p = pairs.clone();
        p.sort_unstable();
        let mut groups = 0usize;
        let mut i = 0;
        while i < p.len() {
            let mut j = i + 1;
            while j < p.len() && p[j].0 == p[i].0 {
                j += 1;
            }
            groups += 1;
            i = j;
        }
        std::hint::black_box(groups);
    });
    let shuffle_rate = report("shuffle sort+group", mn as f64, "pairs", &shuffle_samples);
    doc.insert("shuffle_pairs_per_s".to_string(), Json::Num(shuffle_rate));

    // ── observability overhead: no-telemetry vs disabled vs enabled ──
    // All three modes chunk the same K1 stream into `obs_chunk`-tuple
    // batches, so the telemetry builds pay their per-batch span exactly
    // as often as the serve layer would. The baseline hand-inlines
    // `add_batch` WITHOUT its span — the never-calls-the-recorder build
    // of the identical kernel (PrimeStore::add + generated push).
    use tricluster::obs;
    let obs_chunk = 1024usize;
    println!("\nobs overhead: K1({k1_n}) ingest in {obs_chunk}-tuple batches");
    assert!(!obs::enabled(), "recorder must start disabled");
    let base_samples = measure_ms(1, 7, || {
        let mut primes = PrimeStore::new(3);
        let mut generated: Vec<(SetIds, NTuple)> = Vec::new();
        for chunk in tuples.chunks(obs_chunk) {
            generated.reserve(chunk.len());
            for t in chunk {
                generated.push((primes.add(t), *t));
            }
        }
        std::hint::black_box(generated.len());
    });
    let base_rate =
        report("ingest no-telemetry build", n as f64, "tuples", &base_samples);

    let off_samples = measure_ms(1, 7, || {
        let mut miner = OnlineMiner::new(3);
        for chunk in tuples.chunks(obs_chunk) {
            miner.add_batch(chunk);
        }
        std::hint::black_box(miner.len());
    });
    let off_rate =
        report("ingest telemetry disabled", n as f64, "tuples", &off_samples);

    obs::reset();
    obs::enable();
    let on_samples = measure_ms(1, 7, || {
        let mut miner = OnlineMiner::new(3);
        for chunk in tuples.chunks(obs_chunk) {
            miner.add_batch(chunk);
        }
        std::hint::black_box(miner.len());
        // drop this run's spans so the trace buffer stays bounded — the
        // reset cost is part of what "telemetry on" charges
        obs::reset();
    });
    obs::disable();
    obs::reset();
    let on_rate =
        report("ingest telemetry enabled", n as f64, "tuples", &on_samples);
    let off_ratio = off_rate / base_rate;
    let on_ratio = on_rate / base_rate;
    println!(
        "{:<30} disabled {off_ratio:.3}x / enabled {on_ratio:.3}x of no-telemetry",
        "obs overhead"
    );
    doc.insert("obs_disabled_tuples_per_s".to_string(), Json::Num(off_rate));
    doc.insert("obs_enabled_tuples_per_s".to_string(), Json::Num(on_rate));
    doc.insert("obs_disabled_vs_baseline".to_string(), Json::Num(off_ratio));
    doc.insert("obs_enabled_vs_baseline".to_string(), Json::Num(on_ratio));

    std::fs::write("BENCH_hotpath.json", Json::Obj(doc).to_string())
        .expect("write BENCH_hotpath.json");
    println!(
        "\nwrote BENCH_hotpath.json (batched probe, parallel ingest, parallel \
         dedup, bitset and compressed density all verified against their \
         sequential/scalar oracles; ingest speedup {ratio:.2}x, dedup speedup \
         {dedup_ratio:.2}x, bitset speedup {b:.1}x)",
        b = median_ms(&scalar_samples) / median_ms(&bitset_samples)
    );
}
