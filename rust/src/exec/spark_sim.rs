//! The `SparkSim` backend: adapts the Spark-like RDD engine
//! ([`crate::spark::rdd`]) to the [`Backend`] contract. Map and reduce
//! phases run as narrow (fused, per-partition) transformations, the
//! shuffle as an in-memory wide `group_by_key` — no DFS materialisation,
//! no record encoding. Per-partition task timings land in the shared
//! [`SparkContext::stage_log`], so the virtual cluster clock stays
//! comparable with the Hadoop-style engine.

use anyhow::Result;

use super::backend::{Backend, Data, Key};
use crate::spark::rdd::SparkContext;

/// Spark-like backend over a borrowed [`SparkContext`] (the context owns
/// partitioning config and the stage log, exactly like a driver session).
pub struct SparkSim<'a> {
    sc: &'a SparkContext,
}

impl<'a> SparkSim<'a> {
    /// Backend over an existing Spark-like context.
    pub fn new(sc: &'a SparkContext) -> Self {
        Self { sc }
    }

    /// The underlying context (partitions, stage log).
    pub fn context(&self) -> &SparkContext {
        self.sc
    }
}

impl Backend for SparkSim<'_> {
    fn name(&self) -> &'static str {
        "spark"
    }

    fn map_partitions<I, O, F>(&self, label: &str, input: Vec<I>, f: F) -> Result<Vec<O>>
    where
        I: Data,
        O: Data,
        F: Fn(&I) -> Vec<O> + Sync,
    {
        Ok(self.sc.parallelize(input).flat_map(label, move |x: I| f(&x)).collect())
    }

    fn group_by_key<K, V>(&self, label: &str, pairs: Vec<(K, V)>) -> Result<Vec<(K, Vec<V>)>>
    where
        K: Key,
        V: Data,
    {
        Ok(self.sc.parallelize(pairs).group_by_key(label).collect())
    }

    fn reduce<K, V, O, F>(&self, label: &str, groups: Vec<(K, Vec<V>)>, f: F) -> Result<Vec<O>>
    where
        K: Key,
        V: Data,
        O: Data,
        F: Fn(&K, Vec<V>) -> Vec<O> + Sync,
    {
        Ok(self
            .sc
            .parallelize(groups)
            .flat_map(label, move |(k, vs): (K, Vec<V>)| f(&k, vs))
            .collect())
    }

    /// Fused round: ONE RDD lineage per stage — narrow map, wide
    /// shuffle, narrow reduce — with no driver-side collect between
    /// phases (the composed default would re-parallelize twice).
    fn map_reduce<I, K, V, O, MF, CF, RF>(
        &self,
        label: &str,
        input: Vec<I>,
        map: MF,
        combine: Option<CF>,
        reduce: RF,
    ) -> Result<Vec<O>>
    where
        I: Data,
        K: Key,
        V: Data,
        O: Data,
        MF: Fn(&I) -> Vec<(K, V)> + Sync,
        CF: Fn(&K, Vec<V>) -> Vec<V> + Sync,
        RF: Fn(&K, Vec<V>) -> Vec<O> + Sync,
    {
        let _ = combine;
        Ok(self
            .sc
            .parallelize(input)
            .flat_map(&format!("{label}-map"), move |x: I| map(&x))
            .group_by_key(&format!("{label}-shuffle"))
            .flat_map(&format!("{label}-reduce"), move |(k, vs): (K, Vec<V>)| {
                reduce(&k, vs)
            })
            .collect())
    }

    /// Fused shuffle → reduce over pre-keyed pairs, one RDD lineage.
    fn group_reduce<K, V, O, RF>(
        &self,
        label: &str,
        pairs: Vec<(K, V)>,
        reduce: RF,
    ) -> Result<Vec<O>>
    where
        K: Key,
        V: Data,
        O: Data,
        RF: Fn(&K, Vec<V>) -> Vec<O> + Sync,
    {
        Ok(self
            .sc
            .parallelize(pairs)
            .group_by_key(&format!("{label}-shuffle"))
            .flat_map(&format!("{label}-reduce"), move |(k, vs): (K, Vec<V>)| {
                reduce(&k, vs)
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::no_combine;
    use super::*;

    #[test]
    fn round_runs_and_logs_one_shuffle() {
        let sc = SparkContext::new(4, 2);
        let backend = SparkSim::new(&sc);
        let mut out = backend
            .map_reduce(
                "r",
                (0..60u32).collect::<Vec<_>>(),
                |&x: &u32| vec![(x % 5, 1u64)],
                no_combine::<u32, u64>(),
                |k: &u32, ones: Vec<u64>| vec![(*k, ones.iter().sum())],
            )
            .unwrap();
        out.sort_unstable();
        assert_eq!(out, (0..5).map(|k| (k, 12u64)).collect::<Vec<_>>());
        let log = sc.stage_log.lock().unwrap();
        let labels: Vec<&str> = log.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(labels, vec!["r-map", "r-shuffle", "r-reduce"]);
    }
}
