"""Pallas density kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps tile shapes, cluster-batch sizes, densities, and mask
patterns; exact equality is expected for 0/1 inputs within f32 headroom
(counts ≤ 64³ < 2^24, exactly representable).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import density, ref


def make_case(rng, g, m, b, k, p_t=0.3, p_m=0.5):
    t = (rng.random((g, m, b)) < p_t).astype(np.float32)
    x = (rng.random((k, g)) < p_m).astype(np.float32)
    y = (rng.random((k, m)) < p_m).astype(np.float32)
    z = (rng.random((k, b)) < p_m).astype(np.float32)
    return t, x, y, z


def run_kernel(t, x, y, z, k_block=8):
    return np.asarray(density.density_counts(
        jnp.array(t), jnp.array(x), jnp.array(y), jnp.array(z),
        k_block=k_block))


@settings(max_examples=25, deadline=None)
@given(
    g=st.sampled_from([8, 16, 32]),
    m=st.sampled_from([8, 16]),
    b=st.sampled_from([8, 16]),
    kb=st.sampled_from([1, 2, 4, 8]),
    nblocks=st.integers(1, 3),
    p_t=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_density_matches_ref_hypothesis(g, m, b, kb, nblocks, p_t, seed):
    rng = np.random.default_rng(seed)
    k = kb * nblocks
    t, x, y, z = make_case(rng, g, m, b, k, p_t=p_t)
    got = run_kernel(t, x, y, z, k_block=kb)
    want = np.asarray(ref.density_ref(t, x, y, z))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_empty_masks_give_zero():
    rng = np.random.default_rng(1)
    t, x, y, z = make_case(rng, 16, 16, 16, 8)
    x[3] = 0.0  # empty extent → empty cuboid
    got = run_kernel(t, x, y, z)
    assert got[3] == 0.0


def test_full_masks_count_all_triples():
    rng = np.random.default_rng(2)
    t, _, _, _ = make_case(rng, 16, 8, 8, 8)
    x = np.ones((8, 16), np.float32)
    y = np.ones((8, 8), np.float32)
    z = np.ones((8, 8), np.float32)
    got = run_kernel(t, x, y, z)
    np.testing.assert_allclose(got, np.full(8, t.sum(), np.float32))


def test_dense_tensor_counts_equal_volume():
    # ρ = 1 cuboid: count must equal |X||Y||Z| exactly.
    rng = np.random.default_rng(3)
    t = np.ones((16, 16, 16), np.float32)
    _, x, y, z = make_case(rng, 16, 16, 16, 8)
    got = run_kernel(t, x, y, z)
    vol = x.sum(1) * y.sum(1) * z.sum(1)
    np.testing.assert_allclose(got, vol)


def test_k1_diagonal_context_tile():
    # K1 from the paper: full cuboid minus the g=m=b diagonal. A cluster
    # covering everything must count n³ - n.
    n = 16
    t = np.ones((n, n, n), np.float32)
    for i in range(n):
        t[i, i, i] = 0.0
    ones = np.ones((8, n), np.float32)
    got = run_kernel(t, ones, ones, ones)
    np.testing.assert_allclose(got, np.full(8, n**3 - n, np.float32))


def test_aot_tile_geometry():
    # The exact shape that is lowered to artifacts/density_g64_k32.hlo.txt.
    rng = np.random.default_rng(4)
    t, x, y, z = make_case(rng, 64, 64, 64, 32, p_t=0.1)
    got = run_kernel(t, x, y, z)
    want = np.asarray(ref.density_ref(t, x, y, z))
    np.testing.assert_allclose(got, want)


def test_k_not_multiple_of_block_raises():
    rng = np.random.default_rng(5)
    t, x, y, z = make_case(rng, 8, 8, 8, 6)
    with pytest.raises(ValueError):
        run_kernel(t, x, y, z, k_block=4)


def test_vmem_budget_within_tpu_limits():
    # DESIGN §Hardware-Adaptation: one grid step must fit VMEM (16 MiB).
    assert density.vmem_bytes() < 16 * 2**20
    # and the MXU matmul dominates the work: ≥ 64x the VPU ops.
    assert density.mxu_flops() >= 8 * 64 * 64 * 64
