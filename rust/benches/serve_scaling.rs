//! Bench: serve-layer scaling — ingest throughput, per-batch latency,
//! compaction and query cost as the shard count grows, on the MovieLens
//! stream. Emits `BENCH_serve.json` (repo root) so the perf trajectory
//! is machine-readable across PRs.
//!
//! Quick mode by default; `TRICLUSTER_BENCH_FULL=1` for the 1M-tuple
//! stream. Acceptance target: ≥ 2× ingest throughput at 4 shards vs 1.

use std::collections::BTreeMap;

use tricluster::core::tuple::NTuple;
use tricluster::datasets::{movielens, MovielensParams};
use tricluster::serve::{ServeConfig, TriclusterService};
use tricluster::util::json::Json;
use tricluster::util::stats::{percentile_sorted, Timer};

const BATCH: usize = 8_192;

struct Run {
    shards: usize,
    ingest_ms: f64,
    compact_ms: f64,
    query_ms: f64,
    clusters: usize,
    batch_p50_ms: f64,
    batch_p95_ms: f64,
}

fn drive(tuples: &[NTuple], arity: usize, shards: usize, runs: usize) -> Run {
    let mut best_ingest = f64::INFINITY;
    let mut latencies: Vec<f64> = Vec::new();
    let mut compact_ms = 0.0;
    let mut query_ms = 0.0;
    let mut clusters = 0;
    for _ in 0..runs {
        let mut svc = TriclusterService::new(ServeConfig::new(arity, shards));
        let mut batch_ms = Vec::with_capacity(tuples.len() / BATCH + 1);
        let t = Timer::start();
        for chunk in tuples.chunks(BATCH) {
            let tb = Timer::start();
            svc.ingest(chunk);
            batch_ms.push(tb.elapsed_ms());
        }
        svc.flush();
        let ingest_ms = t.elapsed_ms();
        let t = Timer::start();
        svc.compact();
        let c_ms = t.elapsed_ms();
        let t = Timer::start();
        let q = svc.query();
        let top = q.top_k_by_density(10);
        std::hint::black_box(top.len());
        let q_ms = t.elapsed_ms();
        if ingest_ms < best_ingest {
            best_ingest = ingest_ms;
            latencies = batch_ms;
            compact_ms = c_ms;
            query_ms = q_ms;
            clusters = q.len();
        }
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Run {
        shards,
        ingest_ms: best_ingest,
        compact_ms,
        query_ms,
        clusters,
        batch_p50_ms: percentile_sorted(&latencies, 50.0),
        batch_p95_ms: percentile_sorted(&latencies, 95.0),
    }
}

fn num(n: f64) -> Json {
    Json::Num(n)
}

fn main() {
    let full = std::env::var("TRICLUSTER_BENCH_FULL").is_ok();
    let n = if full { 1_000_000 } else { 200_000 };
    let runs = if full { 1 } else { 3 };
    eprintln!("serve_scaling bench (full={full}, {n} tuples) ...");
    let ctx = movielens(&MovielensParams::with_tuples(n));
    let tuples = ctx.tuples().to_vec();

    let mut series: Vec<Run> = Vec::new();
    for &shards in &[1usize, 2, 4, 8] {
        let run = drive(&tuples, ctx.arity(), shards, runs);
        eprintln!(
            "  {shards} shard(s): ingest {:.0} ms ({:.0} tuples/s) | compact {:.0} ms | \
             query {:.2} ms | {} clusters | batch p50 {:.2} / p95 {:.2} ms",
            run.ingest_ms,
            n as f64 / (run.ingest_ms / 1e3),
            run.compact_ms,
            run.query_ms,
            run.clusters,
            run.batch_p50_ms,
            run.batch_p95_ms
        );
        series.push(run);
    }

    let base = series[0].ingest_ms;
    let speedup_at_4 = series
        .iter()
        .find(|r| r.shards == 4)
        .map(|r| base / r.ingest_ms)
        .unwrap_or(0.0);
    println!(
        "speedup vs 1 shard: {}",
        series
            .iter()
            .map(|r| format!("{}x@{}", (base / r.ingest_ms * 100.0).round() / 100.0, r.shards))
            .collect::<Vec<_>>()
            .join("  ")
    );
    println!("acceptance: ingest speedup at 4 shards = {speedup_at_4:.2} (target ≥ 2.0)");

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("serve_scaling".into()));
    doc.insert("dataset".to_string(), Json::Str("movielens".into()));
    doc.insert("tuples".to_string(), num(n as f64));
    doc.insert("batch".to_string(), num(BATCH as f64));
    doc.insert("runs".to_string(), num(runs as f64));
    doc.insert(
        "cores".to_string(),
        num(tricluster::util::pool::default_workers() as f64),
    );
    doc.insert("speedup_at_4_shards".to_string(), num(speedup_at_4));
    doc.insert(
        "series".to_string(),
        Json::Arr(
            series
                .iter()
                .map(|r| {
                    let mut o = BTreeMap::new();
                    o.insert("shards".to_string(), num(r.shards as f64));
                    o.insert("ingest_ms".to_string(), num(r.ingest_ms));
                    o.insert(
                        "tuples_per_s".to_string(),
                        num(n as f64 / (r.ingest_ms / 1e3)),
                    );
                    o.insert("speedup_vs_1".to_string(), num(base / r.ingest_ms));
                    o.insert("compact_ms".to_string(), num(r.compact_ms));
                    o.insert("query_ms".to_string(), num(r.query_ms));
                    o.insert("clusters".to_string(), num(r.clusters as f64));
                    o.insert("batch_p50_ms".to_string(), num(r.batch_p50_ms));
                    o.insert("batch_p95_ms".to_string(), num(r.batch_p95_ms));
                    Json::Obj(o)
                })
                .collect(),
        ),
    );
    let json = Json::Obj(doc);
    std::fs::write("BENCH_serve.json", json.to_string()).expect("write BENCH_serve.json");
    eprintln!("wrote BENCH_serve.json");
}
